"""MoE dispatch correctness: the capacity-buffer path must equal the dense
per-token reference when capacity is ample, for both router types."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig, MoEConfig


def dense_reference(p, x, cfg: ModelConfig):
    """Every token through its top-k experts, computed directly."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    w, idx, _ = moe_mod.route(p, xf, cfg)
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu

    def expert(eid, v):
        g = act(v @ p["w_gate"][eid])
        u = v @ p["w_up"][eid]
        return (g * u) @ p["w_down"][eid]

    y = jnp.zeros_like(xf)
    for kk in range(m.top_k):
        outs = []
        for ti in range(t):
            outs.append(expert(int(idx[ti, kk]), xf[ti]) * w[ti, kk])
        y = y + jnp.stack(outs)
    y = y.reshape(b, s, d)
    if m.n_shared > 0:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, cfg)
    return y


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "deepseek-v3-671b"])
def test_dispatch_matches_dense_reference(arch):
    cfg = dataclasses.replace(
        configs.get_reduced(arch), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    y, metrics = moe_mod.apply_moe(p, x, cfg)
    y_ref = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(metrics["drop_frac"]) == 0.0


def test_capacity_drops_tokens():
    cfg = dataclasses.replace(configs.get_reduced("qwen2-moe-a2.7b"),
                              dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    _, metrics = moe_mod.apply_moe(p, x, cfg)
    assert float(metrics["drop_frac"]) > 0.0


def test_global_and_sharded_impls_agree():
    cfg = dataclasses.replace(configs.get_reduced("qwen2-moe-a2.7b"),
                              dtype="float32")
    cfg_g = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     impl="global"))
    cfg_s = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     impl="sharded"))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    yg, _ = moe_mod.apply_moe(p, x, cfg_g)
    ys, _ = moe_mod.apply_moe(p, x, cfg_s)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                               rtol=1e-5, atol=1e-5)


def test_sigmoid_router_normalizes():
    cfg = dataclasses.replace(configs.get_reduced("deepseek-v3-671b"),
                              dtype="float32")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, cfg.d_model))
    w, idx, probs = moe_mod.route(p, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones((8,)),
                               rtol=1e-5)
    assert idx.shape == (8, cfg.moe.top_k)


def test_router_bias_balancing_converges():
    """The aux-free bias update drives expert load toward uniform."""
    cfg = dataclasses.replace(configs.get_reduced("deepseek-v3-671b"),
                              dtype="float32")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64, cfg.d_model),
                          jnp.float32)

    def imbalance(bias):
        pp = dict(p, bias=bias)
        _, m = moe_mod.apply_moe(pp, x, cfg)
        load = np.asarray(m["expert_load"], np.float64)
        return load.std() / max(load.mean(), 1e-9), m["expert_load"]

    bias = p["bias"]
    imb0, load = imbalance(bias)
    hist = []
    for _ in range(100):
        bias = moe_mod.update_router_bias(bias, load, gamma=0.002)
        imb, load = imbalance(bias)
        hist.append(imb)
    # steady-state imbalance well below the unbiased router's
    assert np.mean(hist[-10:]) < imb0 * 0.6, (imb0, np.mean(hist[-10:]))
