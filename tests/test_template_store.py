"""Persistent cross-serve template store (runtime/template_store.py).

Engine-level: a second serve() against a warm store must produce greedy
tokens bit-identical to a cold-store serve while actually hitting the
store (entries and their pinned pool blocks survived the inter-stream
drain), per-serve stats must be deltas (no double counting), and
invalidation must drain the pool to zero.  Unit-level: the in-flight
adoption guard, scored eviction, and epoch-stamped invalidation.
"""

import numpy as np
import jax
import pytest

from repro.core import kv_compress
from repro.core.request_cluster import Request
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.kv_pool import BlockPool, PagedKVConfig
from repro.runtime.server import Server, ServerConfig
from repro.runtime.template_store import (TemplateStore,
                                          TemplateStoreConfig)

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")
CCFG = kv_compress.KVCompressConfig(n_clusters=8, iters=4, keep_recent=16,
                                    refresh_every=8)
# pool headroom above the slots' own 8-block footprint: persistent pins
# live in the surplus (a fully-provisioned pool evicts every entry under
# pressure before the serve drains — see the oversubscription test)
PG = PagedKVConfig(block_size=4, pool_blocks=24)
SCFG = dict(batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PG)


@pytest.fixture(scope="module")
def params():
    return tfm.init_params(jax.random.PRNGKey(0), TINY)


def _stream(template_seed=5, sfx_seed=11, n=4, tpl_len=40):
    """Templated burst: one shared template per stream + unique
    suffixes.  Streams with the same template_seed share the template
    (the cross-serve reuse target); uids always start at 0 so repeat
    serves exercise uid recycling."""
    rng = np.random.default_rng(template_seed)
    template = rng.integers(0, 64, size=(tpl_len,)).astype(np.int32)
    sfx_rng = np.random.default_rng(sfx_seed)
    reqs, prompts = [], {}
    for i in range(n):
        sfx = sfx_rng.integers(0, 64, size=(
            int(sfx_rng.integers(3, 9)),)).astype(np.int32)
        prompts[i] = np.concatenate([template, sfx])
        reqs.append(Request(i, len(prompts[i]),
                            int(sfx_rng.integers(6, 12))))
    return reqs, prompts


class TestTemplateStoreEngine:

    def test_warm_serve_bit_identical_hits_and_drains(self, params):
        reqs1, prompts1 = _stream(sfx_seed=11)
        reqs2, prompts2 = _stream(sfx_seed=13)
        cold = Server(TINY, ServerConfig(**SCFG), params)
        ref2 = {o.uid: o.tokens for o in cold.serve(reqs2, prompts2)}

        srv = Server(TINY, ServerConfig(
            template_store=TemplateStoreConfig(), **SCFG), params)
        srv.serve(reqs1, prompts1)
        st1 = dict(srv.last_stats)
        # the store persisted entries + pinned blocks through the drain
        assert st1["template_entries"] > 0
        assert st1["template_pinned_blocks"] > 0
        assert st1["pool_blocks_end"] == 0.0      # nothing beyond pins
        assert srv._tmpl_pool is not None
        assert srv._tmpl_pool.allocated() == srv._store.pinned_blocks()

        outs2 = srv.serve(reqs2, prompts2)
        st2 = dict(srv.last_stats)
        for o in outs2:                            # warm == cold, bitwise
            assert o.tokens == ref2[o.uid], o.uid
        assert st2["prefix_hits"] > 0              # really hit the store
        assert st2["pool_blocks_end"] == 0.0
        # warm start skipped template chunks the cold serve had to feed
        assert st2["prefill_chunks"] < st1["prefill_chunks"]
        # per-serve deltas + lifetime totals (no double counting)
        assert st2["template_hits_total"] == (st1["prefix_hits"]
                                              + st2["prefix_hits"])
        assert st2["template_tokens_reused_total"] == (
            st1["prefix_tokens_reused"] + st2["prefix_tokens_reused"])
        # traffic clustering surfaced per-cluster stats
        assert st2["template_clusters"] >= 1
        assert 0.0 < st2["template_cohesion_mean"] <= 1.0
        assert st2["template_bytes_pinned"] > 0
        assert st2["template_cluster0_hit_rate"] >= 0.0

        # explicit invalidation drains every pinned block
        srv.invalidate_templates()
        assert srv._store.pinned_blocks() == 0
        assert srv._tmpl_pool is None and srv._tmpl_cache is None

    def test_uid_reuse_across_serves_different_prompts(self, params):
        """Duplicate-uid regression (digest memo): serve #2 recycles the
        exact uids of serve #1 for a different template.  Stale
        uid-keyed digests would steer/adopt serve-1 prefixes for
        serve-2 prompts; content verification keeps tokens exact."""
        reqs1, prompts1 = _stream(template_seed=5)
        reqs2, prompts2 = _stream(template_seed=9, sfx_seed=13)
        assert [r.uid for r in reqs1] == [r.uid for r in reqs2]
        cold = Server(TINY, ServerConfig(**SCFG), params)
        ref2 = {o.uid: o.tokens for o in cold.serve(reqs2, prompts2)}

        srv = Server(TINY, ServerConfig(
            template_store=TemplateStoreConfig(), **SCFG), params)
        srv.serve(reqs1, prompts1)
        outs2 = srv.serve(reqs2, prompts2)
        for o in outs2:
            assert o.tokens == ref2[o.uid], o.uid
        # template B never matches template A's entries
        st2 = srv.last_stats
        assert st2["prefix_tokens_reused"] <= sum(
            len(p) for p in prompts2.values())

    def test_oversubscribed_pool_evicts_under_adoption_pressure(self,
                                                                params):
        """Satellite regression: a fully-provisioned pool (zero pin
        headroom) keeps the reclaim path hot — evict_lru fires while
        admissions are adopting entries.  The in-flight guard must keep
        every adoption sound: serves complete, tokens stay bit-identical
        to the cold run, and the drain invariant holds with whatever
        pins survived."""
        tight = dict(SCFG)
        tight["paged"] = PagedKVConfig(block_size=4)   # 8 blocks total
        reqs1, prompts1 = _stream(sfx_seed=11)
        reqs2, prompts2 = _stream(sfx_seed=13)
        cold = Server(TINY, ServerConfig(**tight), params)
        ref2 = {o.uid: o.tokens for o in cold.serve(reqs2, prompts2)}
        srv = Server(TINY, ServerConfig(
            template_store=TemplateStoreConfig(), **tight), params)
        srv.serve(reqs1, prompts1)
        assert srv.last_stats["prefix_hits"] > 0   # sharing ran hot
        outs2 = srv.serve(reqs2, prompts2)
        for o in outs2:
            assert o.tokens == ref2[o.uid], o.uid
        assert srv.last_stats["pool_blocks_end"] == 0.0

    def test_epoch_change_invalidates_shared_store(self, params):
        """A TemplateStore instance reused by a second Server (different
        params ⇒ different epoch) must come up cold — a stale snapshot
        under new weights can never be adopted."""
        reqs, prompts = _stream()
        store = TemplateStore(TemplateStoreConfig())
        srv1 = Server(TINY, ServerConfig(template_store=store, **SCFG),
                      params)
        srv1.serve(reqs, prompts)
        assert store.pinned_blocks() > 0
        inval0 = store.invalidations

        params2 = tfm.init_params(jax.random.PRNGKey(1), TINY)
        cold = Server(TINY, ServerConfig(**SCFG), params2)
        ref = {o.uid: o.tokens for o in cold.serve(reqs, prompts)}
        srv2 = Server(TINY, ServerConfig(template_store=store, **SCFG),
                      params2)
        outs = srv2.serve(reqs, prompts)
        assert store.invalidations > inval0        # epoch flipped
        for o in outs:
            assert o.tokens == ref[o.uid], o.uid


class TestTemplateStoreUnit:

    @staticmethod
    def _registered(store, pool, slot, prompt, fed):
        bis = [bi for bi in range(pool.blocks_per_slot)
               if bi * 4 < fed]
        for bi in bis:
            pool.alloc(slot, bi)
        blocks = {bi: int(pool.table[slot, bi]) for bi in bis}
        store.register(pool.shard_of(slot), prompt, fed, 0, blocks,
                       snap=object())

    def test_inflight_guard_pins_entry_during_adoption(self):
        """The eviction-mid-adoption bug: an entry between lookup and
        restore must survive pool-pressure eviction even when it is the
        scored victim."""
        pool = BlockPool(2, 16, PagedKVConfig(block_size=4,
                                              pool_blocks=16))
        store = TemplateStore(TemplateStoreConfig(max_entries=4))
        store.bind("epoch", 1, pool)
        chunk = 8
        pA = np.arange(24, dtype=np.int32)
        pB = np.arange(24, dtype=np.int32) + 1
        self._registered(store, pool, 0, pA, 8)
        self._registered(store, pool, 1, pB, 8)
        # make B the higher-scored entry, then put A (the victim by
        # score) in flight
        for _ in range(2):
            e = store.lookup(0, pB, chunk,
                             digests=store.prefix_digests(pB, chunk))
            store.adoption_done(e)
        eA = store.lookup(0, pA, chunk,
                          digests=store.prefix_digests(pA, chunk))
        assert eA is not None and eA.in_flight == 1
        assert store.evict_lru(0)                  # must pick B, not A
        assert any(v is eA for v in store._maps[0].values())
        assert not store.evict_lru(0)              # only the pin remains
        with pytest.raises(RuntimeError, match="in flight"):
            store.invalidate()
        store.adoption_done(eA)
        assert store.evict_lru(0)                  # evictable again
        assert store.pinned_blocks() == 0
        for s in range(2):
            pool.free_slot(s)
        assert pool.allocated() == 0
        with pytest.raises(ValueError, match="without a matching"):
            store.adoption_done(eA)

    def test_scored_eviction_keeps_earning_templates(self):
        """hits × tokens-reused beats recency: the entry that keeps
        collapsing admissions survives a newer never-hit entry (pure
        LRU would evict the hot template)."""
        pool = BlockPool(2, 16, PagedKVConfig(block_size=4,
                                              pool_blocks=16))
        store = TemplateStore(TemplateStoreConfig(max_entries=4))
        store.bind("epoch", 1, pool)
        chunk = 8
        hot = np.arange(24, dtype=np.int32)
        decoy = np.arange(24, dtype=np.int32) + 1
        self._registered(store, pool, 0, hot, 8)
        for _ in range(2):
            e = store.lookup(0, hot, chunk,
                             digests=store.prefix_digests(hot, chunk))
            store.adoption_done(e)
        self._registered(store, pool, 1, decoy, 8)   # newest stamp
        assert store.evict_lru(0)
        assert store.match_len(0, hot, chunk) == 8   # hot survived
        assert store.match_len(0, decoy, chunk) == 0

    def test_bind_epoch_and_pool_identity(self):
        pool = BlockPool(2, 16, PagedKVConfig(block_size=4,
                                              pool_blocks=16))
        store = TemplateStore(TemplateStoreConfig())
        assert store.bind("e1", 1, pool)             # cold first bind
        p = np.arange(24, dtype=np.int32)
        TestTemplateStoreUnit._registered(store, pool, 0, p, 8)
        assert not store.bind("e1", 1, pool)         # warm: entries kept
        assert store.pinned_blocks() > 0
        assert store.bind("e2", 1, pool)             # epoch change: cold
        assert store.pinned_blocks() == 0
        TestTemplateStoreUnit._registered(store, pool, 0, p, 8)
        pool2 = BlockPool(2, 16, PagedKVConfig(block_size=4,
                                               pool_blocks=16))
        assert store.bind("e2", 1, pool2)            # pool change: cold
        assert store.pinned_blocks() == 0

    def test_promotion_assigns_recurring_family(self):
        """Mettu–Plaxton-style medoid promotion: an unmatched prompt
        family becomes a cluster once it recurs promote_after times."""
        store = TemplateStore(TemplateStoreConfig(promote_after=2))
        store.bind("e", 1, BlockPool(
            2, 16, PagedKVConfig(block_size=4, pool_blocks=16)))
        chunk = 8
        p = np.arange(24, dtype=np.int32)
        d = store.prefix_digests(p, chunk)
        assert store.assign(p, d) == -1              # first sighting
        cid = store.assign(p, d)                     # recurrence: promote
        assert cid >= 0
        assert store.assign(p, d) == cid             # sticky
        stats = store.stats()
        assert stats["template_clusters"] == 1.0


class TestContentHashEpoch:

    def test_same_bytes_new_pytree_keeps_pins(self, params):
        """Epoch regression: the params component of the store epoch is
        a CONTENT hash, not object identity — a rebuilt pytree with
        byte-identical weights (a reloaded checkpoint, a device
        round-trip) must warm-bind and keep every pinned block.  (The
        different-PRNGKey test above still proves different bytes DO
        invalidate.)"""
        reqs1, prompts1 = _stream(sfx_seed=11)
        reqs2, prompts2 = _stream(sfx_seed=13)
        cold = Server(TINY, ServerConfig(**SCFG), params)
        ref2 = {o.uid: o.tokens for o in cold.serve(reqs2, prompts2)}

        store = TemplateStore(TemplateStoreConfig())
        srv1 = Server(TINY, ServerConfig(template_store=store, **SCFG),
                      params)
        srv1.serve(reqs1, prompts1)
        assert store.pinned_blocks() > 0
        inval0 = store.invalidations

        # fresh leaves, identical bytes: id() differs on every array
        params_copy = jax.tree_util.tree_map(
            lambda x: jax.numpy.array(np.asarray(x)), params)
        assert all(a is not b for a, b in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(params_copy)))
        srv2 = Server(TINY, ServerConfig(template_store=store, **SCFG),
                      params_copy)
        outs = srv2.serve(reqs2, prompts2)
        assert store.invalidations == inval0       # warm bind, pins kept
        assert srv2.last_stats["prefix_hits"] > 0  # the reuse is real
        for o in outs:
            assert o.tokens == ref2[o.uid], o.uid


class TestMedoidRetirement:

    def test_recurrence_decay_retires_dead_clusters(self):
        """A medoid whose cluster sees no member/hit/registration for
        ``retire_after`` assign ticks is pruned; its entries
        de-associate (cluster -> -1) but keep their blocks; a later
        recurrence of the same family re-promotes from scratch."""
        pool = BlockPool(2, 16, PagedKVConfig(block_size=4,
                                              pool_blocks=16))
        store = TemplateStore(TemplateStoreConfig(promote_after=2,
                                                  retire_after=4))
        store.bind("epoch", 1, pool)
        p = np.arange(10, dtype=np.int32)
        digA, digB = [(8, b"A")], [(8, b"B")]
        assert store.assign(p, digA) == -1         # family below threshold
        cid_a = store.assign(p, digA)              # promoted
        assert cid_a >= 0
        # give A a registered entry so de-association is observable
        TestTemplateStoreUnit._registered(store, pool, 0, p, 8)
        entry = next(iter(store._maps[0].values()))
        entry.cluster = cid_a
        # B stays active while A idles past the horizon
        for _ in range(6):
            store.assign(p, digB)
        assert cid_a not in store._clusters        # A retired
        assert store.clusters_retired == 1
        assert store.stats()["template_clusters_retired"] == 1.0
        assert entry.cluster == -1                 # entry de-associated
        assert store.pinned_blocks() > 0           # ... blocks untouched
        # the B cluster survived (it kept recurring)
        assert any(c.medoid == b"B" for c in store._clusters.values())
        # A's family restarts cold: promotion threshold applies again
        assert store.assign(p, digA) == -1
        cid_a2 = store.assign(p, digA)
        assert cid_a2 >= 0 and cid_a2 != cid_a

    def test_stale_family_counts_decay(self):
        """Unpromoted family recurrences expire on the same clock, so a
        slow drip of once-seen prompts cannot grow _families without
        bound (nor promote via ancient sightings)."""
        store = TemplateStore(TemplateStoreConfig(promote_after=2,
                                                  retire_after=3))
        p = np.arange(10, dtype=np.int32)
        store.assign(p, [(8, b"X")])               # X seen once
        for i in range(5):                         # unrelated traffic
            store.assign(p, [(8, bytes([i]))])
        assert b"X" not in store._families         # decayed, not counted
        # a fresh sighting starts over at 1 -> still below threshold
        assert store.assign(p, [(8, b"X")]) == -1

    def test_retire_disabled_by_default(self):
        store = TemplateStore(TemplateStoreConfig(promote_after=1))
        p = np.arange(10, dtype=np.int32)
        cid = store.assign(p, [(8, b"A")])
        assert cid >= 0
        for i in range(200):
            store.assign(p, [(8, bytes([i % 250]))])
        assert cid in store._clusters              # never retired
        assert store.clusters_retired == 0
