"""SLO-aware scheduling (runtime/scheduler.py + engine integration).

Unit level: victim selection (strictly-lower priority, cheapest =
fewest mapped blocks), backlog ordering (priority then swap-out FIFO,
holds respected), shed eligibility (protected class refuses), and the
priority-aware batch planner.  Engine level: preempting a best-effort
slot to host memory and resuming it mid-stream must be
schedule-invisible — greedy tokens bit-identical to an uninterrupted
big-pool run — in chunked and blocking admission, and overload must
brown out (defer → preempt → shed best-effort) instead of raising
``PoolExhausted`` while the protected class completes untouched.
"""

import numpy as np
import pytest

import jax

from repro.core import kv_compress
from repro.core.request_cluster import Request, plan_batches
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.kv_pool import BlockPool, PagedKVConfig
from repro.runtime.scheduler import SLOConfig, SLOScheduler, SwapRecord
from repro.runtime.server import Server, ServerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")
CCFG = kv_compress.KVCompressConfig(n_clusters=8, iters=4, keep_recent=16,
                                    refresh_every=8)


@pytest.fixture(scope="module")
def params():
    return tfm.init_params(jax.random.PRNGKey(0), TINY)


def _mixed_stream(n=8, n_high=3, seed=3, vocab=64):
    """FIFO-order stream with the high-priority tail: best-effort
    requests arrive first and occupy every slot, so the late
    interactive arrivals can only be served by preempting them."""
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        plen = int(rng.integers(6, 30))
        prompts[i] = rng.integers(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(i, plen, int(rng.integers(6, 14)),
                            priority=1 if i >= n - n_high else 0))
    return reqs, prompts


def _serve(scfg, params, reqs, prompts):
    srv = Server(TINY, scfg, params)
    outs = srv.serve(reqs, prompts)
    return {o.uid: o for o in outs}, srv.last_stats


# ---------------------------------------------------------------------------
# unit: SLOScheduler policy
# ---------------------------------------------------------------------------


def _rec(uid, priority, seq=0, n_blocks=1, hold=False):
    return SwapRecord(uid=uid, priority=priority, pos=4, cur=1, fed=4,
                      since_tok=0, cov=0, max_new_tokens=4, deadline_ms=0.0,
                      held={0: (uid, 0)}, snap=None, tails=None, epoch=0,
                      seq=seq, n_blocks_swapped=n_blocks, hold=hold)


class TestSLOSchedulerUnit:

    def test_pick_victim_strictly_lower_and_cheapest(self):
        slo = SLOScheduler(SLOConfig(), 4)
        cands = [(0, 3, 0), (0, 1, 1), (1, 0, 2)]
        # cheapest among strictly-lower classes: fewest mapped blocks
        assert slo.pick_victim(cands, 1) == 1
        # nothing strictly below the lowest class
        assert slo.pick_victim(cands, 0) is None
        # within-class never picked unless the caller raises the bar
        assert slo.pick_victim([(1, 2, 0), (1, 1, 3)], 1) is None
        assert slo.pick_victim([(1, 2, 0), (1, 1, 3)], 2) == 3
        assert slo.pick_victim([], 5) is None

    def test_backlog_resume_order_and_holds(self):
        slo = SLOScheduler(SLOConfig(), 4)
        a, b, c = _rec(0, 0), _rec(1, 1), _rec(2, 1)
        for r in (a, b, c):
            slo.record_swap(r)
        # highest class first, FIFO within the class
        assert slo.peek_resume() is b
        b.hold = True
        assert slo.peek_resume() is c          # held records are skipped
        c.hold = True
        assert slo.peek_resume() is a
        a.hold = True
        assert slo.peek_resume() is None
        slo.clear_holds()                      # forward progress happened
        assert slo.peek_resume() is b
        slo.pop_record(b)
        assert slo.peek_resume() is c
        assert slo.swaps_in == 1
        assert slo.backlog_size() == 2

    def test_swap_cap_defaults_to_slot_count(self):
        slo = SLOScheduler(SLOConfig(), 2)
        assert slo.can_swap()
        slo.record_swap(_rec(0, 0))
        slo.record_swap(_rec(1, 0))
        assert not slo.can_swap()
        assert SLOScheduler(SLOConfig(max_swapped=5), 2).max_swapped == 5

    def test_shed_protects_high_class(self):
        slo = SLOScheduler(SLOConfig(high_class=1), 4)
        lo, hi = _rec(0, 0), _rec(1, 2)
        slo.record_swap(lo)
        slo.record_swap(hi)
        assert slo.pick_shed() is lo           # never offers the high one
        with pytest.raises(RuntimeError):
            slo.shed_record(hi)
        with pytest.raises(RuntimeError):
            slo.shed_uid(9, 1)
        slo.shed_record(lo)
        assert slo.pick_shed() is None         # only protected work parked
        assert slo.shed_uids == {0}
        assert slo.shed_high == 0

    def test_shed_lifo_within_class(self):
        # the longest-parked equal has the best claim on resuming, so
        # the most recently parked one sheds first
        slo = SLOScheduler(SLOConfig(), 4)
        first, second = _rec(0, 0), _rec(1, 0)
        slo.record_swap(first)
        slo.record_swap(second)
        assert slo.pick_shed() is second

    def test_stats_keys_complete(self):
        st = SLOScheduler(SLOConfig(), 2).stats()
        for k in ("sched_deferrals", "sched_preemptions", "sched_swaps_out",
                  "sched_swaps_in", "sched_sheds", "sched_shed_high",
                  "sched_swapped_peak_blocks", "sched_readopted_blocks",
                  "sched_reuploaded_blocks", "sched_swap_bytes",
                  "sched_backlog_end"):
            assert st[k] == 0.0


class TestResumeDemand:
    """The resume headroom gate must charge a resume only for blocks the
    readopt fast path would actually re-upload — (gid, gen)-surviving
    blocks cost nothing (ROADMAP item 3: the whole-ring estimate
    deferred resumes the pool could in fact serve)."""

    def _pool(self, **kw):
        return BlockPool(4, 16, PagedKVConfig(block_size=4,
                                              pool_blocks=16), **kw)

    def test_counts_only_truly_fresh_blocks(self):
        pool = self._pool()
        for bi in range(4):
            pool.alloc(0, bi)
        # blocks 0/1 stay referenced across the release (prefix-cache
        # pin / other adopter) → readopt survives; 2/3 recycle → fresh
        pinned = [int(pool.table[0, bi]) for bi in (0, 1)]
        for gid in pinned:
            pool.retain(gid)
        held = pool.release_slot(0)
        assert len(held) == 4
        assert pool.resume_demand(0, held) == 2

    def test_matches_readopt_outcomes_and_is_read_only(self):
        pool = self._pool()
        for bi in range(4):
            pool.alloc(0, bi)
        for bi in (1, 3):
            pool.retain(int(pool.table[0, bi]))
        held = pool.release_slot(0)
        # churn the free list so released gids recycle with bumped gens
        for bi in range(4):
            pool.alloc(1, bi)
        demand = pool.resume_demand(0, held)
        before = (pool.allocated(), pool.free_blocks(0),
                  pool.table.copy().tolist())
        assert pool.resume_demand(0, held) == demand   # idempotent
        assert (pool.allocated(), pool.free_blocks(0),
                pool.table.tolist()) == before         # read-only
        survived = sum(pool.readopt(0, bi, gid, gen)
                       for bi, (gid, gen) in held.items())
        assert demand == len(held) - survived

    def test_full_readopt_costs_nothing(self):
        pool = self._pool()
        for bi in range(4):
            pool.alloc(0, bi)
        for bi in range(4):
            pool.retain(int(pool.table[0, bi]))
        held = pool.release_slot(0)
        assert pool.resume_demand(0, held) == 0

    def test_cross_shard_blocks_are_fresh(self):
        pool = self._pool(n_shards=2)
        for bi in range(4):
            pool.alloc(0, bi)                          # shard 0 blocks
        for bi in range(4):
            pool.retain(int(pool.table[0, bi]))
        held = pool.release_slot(0)
        assert pool.resume_demand(0, held) == 0
        # a shard-1 slot can never readopt shard-0 blocks
        assert pool.resume_demand(2, held) == 4


class TestPriorityPlanning:

    def test_plan_batches_orders_classes(self):
        reqs = [Request(i, 10 + i, 4, priority=i % 3) for i in range(9)]
        plan = plan_batches(reqs, batch_size=2, n_clusters=2, seed=0)
        by_uid = {r.uid: r.priority for r in reqs}
        prios = [max(by_uid[u] for u in b) for b in plan.batches]
        # every batch is single-class and classes appear high→low
        for b in plan.batches:
            assert len({by_uid[u] for u in b}) == 1
        assert prios == sorted(prios, reverse=True)
        assert sorted(u for b in plan.batches for u in b) == list(range(9))

    def test_single_class_plan_unchanged(self):
        reqs = [Request(i, 10 + 3 * i, 4) for i in range(6)]
        base = plan_batches(reqs, batch_size=2, n_clusters=2, seed=0)
        tagged = [Request(i, 10 + 3 * i, 4, priority=5) for i in range(6)]
        same = plan_batches(tagged, batch_size=2, n_clusters=2, seed=0)
        assert base.batches == same.batches


# ---------------------------------------------------------------------------
# engine: preemption is schedule-invisible
# ---------------------------------------------------------------------------


class TestEnginePreemption:

    def _ref(self, params, reqs, prompts, chunk):
        outs, _ = _serve(ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG,
            prefill_chunk=chunk,
            paged=PagedKVConfig(block_size=4, pool_blocks=48),
            use_clustered_batching=False), params, reqs, prompts)
        return {u: o.tokens for u, o in outs.items()}

    @pytest.mark.parametrize("chunk", [8, 0], ids=["chunked", "blocking"])
    def test_preempt_swap_resume_bit_identical(self, params, chunk):
        """Tight pool + late-arriving high-priority requests: the engine
        must preempt best-effort slots to host memory and resume them,
        with every completed request's greedy tokens bit-identical to an
        uninterrupted big-pool run (mid-stream compaction in play)."""
        reqs, prompts = _mixed_stream()
        ref = self._ref(params, reqs, prompts, chunk)
        outs, st = _serve(ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG,
            prefill_chunk=chunk,
            paged=PagedKVConfig(block_size=4, pool_blocks=10),
            use_clustered_batching=False,
            # arrival-order admission: priority must act through
            # preemption alone (the path this test pins)
            scheduler=SLOConfig(priority_admission=False)),
            params, reqs, prompts)
        assert st["sched_preemptions"] >= 1.0      # really preempted
        assert st["sched_swaps_in"] >= 1.0         # ... and resumed
        assert st["sched_shed_high"] == 0.0
        assert sorted(outs) == sorted(r.uid for r in reqs)
        for uid, o in outs.items():
            if o.shed:
                assert not (uid >= 5)              # only best-effort sheds
                continue
            assert o.tokens == ref[uid], uid
        # protected class always completes in full
        for r in reqs:
            if r.priority >= 1:
                assert not outs[r.uid].shed
                assert len(outs[r.uid].tokens) == r.max_new_tokens

    def test_overload_browns_out_instead_of_raising(self, params):
        """A pool far too small for the offered load must shed
        best-effort work (partial tokens, ``shed`` flag) rather than
        raise PoolExhausted, and still complete every protected
        request bit-identically."""
        reqs, prompts = _mixed_stream(n=10, n_high=3, seed=5)
        ref = self._ref(params, reqs, prompts, 8)
        outs, st = _serve(ServerConfig(
            batch_size=4, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=9),
            use_clustered_batching=False,
            scheduler=SLOConfig(priority_admission=False)),
            params, reqs, prompts)
        assert st["sched_shed_high"] == 0.0
        for r in reqs:
            o = outs[r.uid]
            if r.priority >= 1:
                assert not o.shed
                assert o.tokens == ref[r.uid]
            elif not o.shed:
                assert o.tokens == ref[r.uid]

    def test_priority_admission_orders_protected_first(self, params):
        """Default admission control: the protected class admits ahead
        of the best-effort backlog it arrived behind, so every
        protected TTFT beats every best-effort TTFT — and tokens stay
        bit-identical to the unpressured run (ordering moves waiting
        around, never token streams)."""
        reqs, prompts = _mixed_stream()
        ref = self._ref(params, reqs, prompts, 8)
        outs, st = _serve(ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=10),
            use_clustered_batching=False,
            scheduler=SLOConfig()), params, reqs, prompts)
        assert st["sched_shed_high"] == 0.0
        prio = {r.uid: r.priority for r in reqs}
        hi = [o.prefill_ms for o in outs.values() if prio[o.uid] >= 1]
        lo = [o.prefill_ms for o in outs.values()
              if prio[o.uid] == 0 and not o.shed]
        assert hi and lo and max(hi) < min(lo)
        for uid, o in outs.items():
            if not o.shed:
                assert o.tokens == ref[uid], uid

    def test_deadline_shed_only_best_effort(self, params):
        """An expired best-effort TTFT deadline sheds the request at its
        next failed admission; protected requests never deadline-shed."""
        rng = np.random.default_rng(11)
        reqs, prompts = [], {}
        for i in range(8):
            plen = int(rng.integers(12, 30))
            prompts[i] = rng.integers(0, 64, size=(plen,)).astype(np.int32)
            # ancient deadline on the best-effort tail: any admission
            # failure sheds it immediately
            reqs.append(Request(i, plen, 8,
                                priority=1 if i < 2 else 0,
                                deadline_ms=0.0 if i < 2 else 1e-6))
        outs, st = _serve(ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=8),
            use_clustered_batching=False,
            scheduler=SLOConfig(priority_admission=False)),
            params, reqs, prompts)
        assert st["sched_shed_high"] == 0.0
        for r in reqs:
            if r.priority >= 1:
                assert not outs[r.uid].shed

    def test_scheduler_requires_paged_clustered_continuous(self, params):
        with pytest.raises(ValueError):
            Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                      scheduler=SLOConfig()), params)
        with pytest.raises(ValueError):
            Server(TINY, ServerConfig(
                batch_size=2, max_seq=64, kv_compress=CCFG,
                scheduler=SLOConfig()), params)

    def test_no_scheduler_stats_absent(self, params):
        reqs, prompts = _mixed_stream(n=3, n_high=0)
        _, st = _serve(ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4)), params, reqs, prompts)
        assert "sched_preemptions" not in st
