"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs; plus a
prefill + decode-step consistency pass for every arch with a decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

ARCHS = list(configs.ARCH_IDS)


def make_batch(cfg: ModelConfig, rng, batch=2, seq=32):
    r = np.random.default_rng(rng)
    out = {}
    s_tok = seq
    if cfg.is_encdec:
        s_enc = seq // 2
        s_tok = seq // 2
        out["enc_embeds"] = jnp.asarray(
            r.normal(size=(batch, s_enc, cfg.d_model)).astype(np.float32))
    elif cfg.n_frontend_tokens:
        s_tok = seq - cfg.n_frontend_tokens
        out["frontend_embeds"] = jnp.asarray(
            r.normal(size=(batch, cfg.n_frontend_tokens,
                           cfg.d_model)).astype(np.float32))
    out["tokens"] = jnp.asarray(
        r.integers(0, cfg.vocab, size=(batch, s_tok)).astype(np.int32))
    labels = r.integers(0, cfg.vocab, size=(batch, s_tok)).astype(np.int32)
    labels[:, -1] = -1
    out["labels"] = jnp.asarray(labels)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_reduced(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 0)
    loss, metrics = jax.jit(
        lambda p, b: tfm.train_loss(p, cfg, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss={loss}"
    # one grad step exists and is finite for a couple of leaves
    g = jax.grad(lambda p: tfm.train_loss(p, cfg, batch, remat=True)[0])(
        params)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves[:5])


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = configs.get_reduced(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 1)
    max_seq = 48
    tok = batch["tokens"]
    logits, cache = jax.jit(lambda p, b: tfm.prefill(
        p, cfg, b["tokens"], max_seq=max_seq,
        frontend_embeds=b.get("frontend_embeds"),
        enc_embeds=b.get("enc_embeds")))(params, batch)
    v = cfg.padded_vocab
    assert logits.shape == (2, v)
    assert bool(jnp.isfinite(logits).all()), arch

    t0 = tok.shape[1] + (cfg.n_frontend_tokens if not cfg.is_encdec else 0)
    step = jax.jit(lambda p, c, tk, t: tfm.decode_step(p, cfg, c, tk, t))
    tk = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(3):
        logits, cache = step(params, cache, tk, jnp.int32(t0 + i))
        assert logits.shape == (2, v)
        assert bool(jnp.isfinite(logits).all()), f"{arch} step {i}"
        tk = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
