"""Mesh-sharded continuous serving engine tests (multi-host harness).

Run in a subprocess with XLA_FLAGS forcing 8 host devices (the main test
process must keep the default single device, per the dry-run contract).
On a 2x4 (data, model) mesh the sharded engine must emit greedy tokens
bit-identical to the single-device engine for mixed-length request
streams — with and without mid-stream clustered-KV compaction.  The
decode paths keep this exact by construction: per-(slot, head) work is
embarrassingly parallel, the Pallas kernel runs per shard via shard_map,
and heads are gathered to a replicated layout before the wo contraction
so no float reduction is reordered.

Also pins the engine-cache partition specs (slots over data, kv heads
over model, divisibility-aware fallback) without needing extra devices.
"""

import pytest

from _subproc import run_sub


_COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import kv_compress
    from repro.core.request_cluster import Request
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig
    from repro.runtime.server import Server, ServerConfig

    assert len(jax.devices()) == 8
    CFG = ModelConfig(name="tiny4", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab=64, pad_vocab_multiple=16, dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    # mixed-length stream: short and long prompts, ragged token budgets,
    # more requests than slots so admission churns mid-stream
    reqs = [Request(i, int(l), g) for i, (l, g) in enumerate(
        [(5, 4), (23, 6), (9, 3), (17, 5), (6, 1), (21, 4), (12, 5),
         (30, 2), (8, 6)])]
    prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    mesh = make_serving_mesh("2x4")
"""


@pytest.mark.slow
def test_sharded_engine_greedy_parity():
    """2x4 mesh tokens == single-device tokens, bit-identical, exact KV."""
    run_sub(_COMMON + """
    ref = Server(CFG, ServerConfig(batch_size=4, max_seq=64), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    srv = Server(CFG, ServerConfig(batch_size=4, max_seq=64, mesh=mesh),
                 params)
    outs = srv.serve(reqs, prompts)
    assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
    for o in outs:
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    # the engine really ran sharded: per-data-shard stats were recorded
    assert srv.last_stats["n_data_shards"] == 2.0
    assert "slot_waste_shard1" in srv.last_stats
    print("sharded greedy parity OK")
    """)


@pytest.mark.slow
def test_sharded_engine_parity_with_midstream_compaction():
    """Same stream served from a clustered KV cache with mid-stream
    re-compaction: mesh tokens must still be bit-identical to the
    single-device compacting engine (same approximation, same bits)."""
    run_sub(_COMMON + """
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    ref = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                   kv_compress=ccfg), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    srv = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                   kv_compress=ccfg, mesh=mesh), params)
    outs = srv.serve(reqs, prompts)
    assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
    for o in outs:
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    print("sharded compaction parity OK")
    """)


@pytest.mark.slow
def test_sharded_chunked_prefill_parity():
    """Chunked, decode-interleaved prefill on a 2x4 mesh: admission
    streams prompt chunks into the already-sharded engine cache (no B=1
    cache, no mesh replication), one admitting slot per data shard, and
    greedy tokens must stay bit-identical to the single-device chunked
    engine — exact KV and clustered KV with mid-stream compaction +
    absorb both."""
    run_sub(_COMMON + """
    ref = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                   prefill_chunk=8), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    srv = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                   prefill_chunk=8, mesh=mesh), params)
    outs = srv.serve(reqs, prompts)
    assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
    for o in outs:
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    assert srv.last_stats["prefill_chunks"] > 0
    assert srv.last_stats["prefill_pad_frac"] == 0.0

    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    ref_c = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                     kv_compress=ccfg, prefill_chunk=8),
                   params)
    refc_out = {o.uid: o.tokens for o in ref_c.serve(reqs, prompts)}
    srv_c = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                     kv_compress=ccfg, prefill_chunk=8,
                                     mesh=mesh), params)
    outs_c = srv_c.serve(reqs, prompts)
    for o in outs_c:
        assert o.tokens == refc_out[o.uid], (o.uid, o.tokens,
                                             refc_out[o.uid])
    assert srv_c.last_stats["kv_absorbs"] > 0
    print("sharded chunked prefill parity OK")
    """)


@pytest.mark.slow
def test_sharded_paged_parity():
    """Paged memory manager on a 2x4 mesh: the block pool shards over the
    data axis like slots (global block ids rebased per shard inside the
    shard_map island), packed ragged rows shard per data shard, and
    greedy tokens must stay bit-identical to BOTH the single-device paged
    engine and the dense clustered engine — blocking and chunked
    admission, with streaming absorbs in play."""
    run_sub(_COMMON + """
    from repro.runtime.kv_pool import PagedKVConfig
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    pg = PagedKVConfig(block_size=4)
    for chunk in (0, 8):
        ref = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                       kv_compress=ccfg,
                                       prefill_chunk=chunk, paged=pg),
                     params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        dense = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                         kv_compress=ccfg,
                                         prefill_chunk=chunk), params)
        dense_out = {o.uid: o.tokens for o in dense.serve(reqs, prompts)}
        srv = Server(CFG, ServerConfig(batch_size=4, max_seq=64,
                                       kv_compress=ccfg,
                                       prefill_chunk=chunk, paged=pg,
                                       mesh=mesh), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], (chunk, o.uid)
            assert o.tokens == dense_out[o.uid], (chunk, o.uid)
        assert srv.last_stats["pool_blocks_end"] == 0.0
        if chunk:
            assert srv.last_stats["kv_absorbs"] > 0
    print("sharded paged parity OK")
    """)


@pytest.mark.slow
def test_sharded_prefix_sharing_parity():
    """Prefix-shared paged admission on a 2x4 mesh: prefix maps are kept
    per data shard (block ids are shard-local), admission steers
    same-prefix requests toward shards already holding an entry, and the
    centroid snapshot crosses shards via place_prefix_snapshot.  Greedy
    tokens must stay bit-identical to BOTH unshared mesh serving and the
    single-device shared run — with mid-stream compaction in play."""
    run_sub(_COMMON + """
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.prefix_cache import PrefixShareConfig
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    pg = PagedKVConfig(block_size=4)
    # templated burst: one shared 40-token template + short suffixes
    tpl = rng.integers(0, 64, size=(40,)).astype(np.int32)
    treqs, tprompts = [], {}
    for i in range(8):
        sfx = rng.integers(0, 64, size=(int(rng.integers(3, 9)),))
        tprompts[i] = np.concatenate([tpl, sfx]).astype(np.int32)
        treqs.append(Request(i, len(tprompts[i]), int(rng.integers(6, 12))))

    def toks_of(scfg):
        srv = Server(CFG, scfg, params)
        outs = srv.serve(treqs, tprompts)
        return {o.uid: o.tokens for o in outs}, srv.last_stats

    unshared_mesh, _ = toks_of(ServerConfig(
        batch_size=4, max_seq=96, kv_compress=ccfg, prefill_chunk=8,
        paged=pg, mesh=mesh))
    shared_1dev, st1 = toks_of(ServerConfig(
        batch_size=4, max_seq=96, kv_compress=ccfg, prefill_chunk=8,
        paged=pg, prefix_share=PrefixShareConfig()))
    shared_mesh, stm = toks_of(ServerConfig(
        batch_size=4, max_seq=96, kv_compress=ccfg, prefill_chunk=8,
        paged=pg, prefix_share=PrefixShareConfig(), mesh=mesh))
    for uid in unshared_mesh:
        assert shared_mesh[uid] == unshared_mesh[uid], uid
        assert shared_mesh[uid] == shared_1dev[uid], uid
    assert st1["prefix_hits"] > 0
    assert stm["prefix_hits"] > 0       # shard-local maps still get hits
    assert stm["pool_blocks_end"] == 0.0
    print("sharded prefix sharing parity OK")
    """)


@pytest.mark.slow
def test_sharded_template_store_warm_parity():
    """Persistent template store on a 2x4 mesh: per-data-shard entries
    and their pinned pool blocks survive the inter-serve drain, the warm
    second serve is bit-identical to BOTH a cold-store mesh serve and
    the warm single-device serve (with warm hits > 0), and
    invalidate_templates() drains the shared pool to zero."""
    run_sub(_COMMON + """
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.template_store import TemplateStoreConfig
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    # pool headroom above full slot provisioning: persistent pins live
    # in the surplus — a zero-surplus pool pressure-evicts every entry
    # before the drain and nothing survives to the second serve
    pg = PagedKVConfig(block_size=4, pool_blocks=24)
    tpl = rng.integers(0, 64, size=(40,)).astype(np.int32)

    def burst(sfx_seed):
        r2 = np.random.default_rng(sfx_seed)
        treqs, tprompts = [], {}
        for i in range(8):
            sfx = r2.integers(0, 64, size=(int(r2.integers(3, 9)),))
            tprompts[i] = np.concatenate([tpl, sfx]).astype(np.int32)
            treqs.append(Request(i, len(tprompts[i]),
                                 int(r2.integers(6, 12))))
        return treqs, tprompts

    reqs1, prompts1 = burst(11)
    reqs2, prompts2 = burst(13)

    def scfg(store, use_mesh):
        return ServerConfig(
            batch_size=4, max_seq=96, kv_compress=ccfg, prefill_chunk=8,
            paged=pg,
            template_store=TemplateStoreConfig() if store else None,
            mesh=mesh if use_mesh else None)

    cold = Server(CFG, scfg(False, True), params)
    ref2 = {o.uid: o.tokens for o in cold.serve(reqs2, prompts2)}
    one = Server(CFG, scfg(True, False), params)
    one.serve(reqs1, prompts1)
    one2 = {o.uid: o.tokens for o in one.serve(reqs2, prompts2)}
    srv = Server(CFG, scfg(True, True), params)
    srv.serve(reqs1, prompts1)
    assert srv.last_stats["template_pinned_blocks"] > 0
    outs = srv.serve(reqs2, prompts2)
    st = srv.last_stats
    for o in outs:
        assert o.tokens == ref2[o.uid], o.uid
        assert o.tokens == one2[o.uid], o.uid
    assert st["prefix_hits"] > 0          # warm across the serve gap
    assert st["pool_blocks_end"] == 0.0
    srv.invalidate_templates()
    assert srv._store.pinned_blocks() == 0
    print("sharded template store warm parity OK")
    """)


@pytest.mark.slow
def test_sharded_windowed_paged_parity():
    """Sliding-window ('GL') serving on a 2x4 mesh: 'L' layers retire
    behind WindowRetention (dense window rings, per-row wlo mask), 'G'
    layers stay clustered behind FrontierRetention — chunked + paged
    mesh tokens must be bit-identical to blocking dense single-device
    admission (the tentpole exit criterion)."""
    run_sub(_COMMON + """
    from repro.runtime.kv_pool import PagedKVConfig
    import dataclasses as dc
    glcfg = dc.replace(CFG, name="tiny-gl4", layer_pattern="GL",
                       sliding_window=16)
    pgl = tfm.init_params(jax.random.PRNGKey(2), glcfg)
    # prompts fit the tail ring (loss-free clustered admission) but
    # exceed the 16-token window; budgets push past keep_recent so
    # compactions advance the 'G' frontier mid-decode
    wreqs = [Request(i, int(l), g) for i, (l, g) in enumerate(
        [(26, 10), (12, 6), (20, 8), (8, 5), (24, 7), (15, 6)])]
    wprompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in wreqs}
    ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                        keep_recent=32, refresh_every=4)
    ref = Server(glcfg, ServerConfig(batch_size=4, max_seq=64,
                                     kv_compress=ccfg), pgl)
    ref_out = {o.uid: o.tokens for o in ref.serve(wreqs, wprompts)}
    srv = Server(glcfg, ServerConfig(batch_size=4, max_seq=64,
                                     kv_compress=ccfg, prefill_chunk=8,
                                     paged=PagedKVConfig(block_size=4),
                                     mesh=mesh), pgl)
    outs = srv.serve(wreqs, wprompts)
    assert sorted(o.uid for o in outs) == sorted(r.uid for r in wreqs)
    for o in outs:
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    st = srv.last_stats
    assert st["kv_retired_window"] > 0 and st["kv_retired_frontier"] > 0
    assert st["pool_blocks_end"] == 0.0
    print("sharded windowed paged parity OK")
    """)


@pytest.mark.slow
def test_sharded_slo_preemption_parity():
    """SLO scheduler on a 2x4 mesh with a pool too small for the
    stream: low-priority slots get preempted (tail ring + centroid
    snapshot swapped to host), resumed mid-stream — and every request
    that isn't shed must emit tokens bit-identical to an unpressured
    single-device serve without a scheduler.  Preemption must be
    schedule-invisible across both the mesh and the swap round-trip."""
    run_sub(_COMMON + """
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.scheduler import SLOConfig
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    # oversubscribed mixed-priority stream: 10 requests onto 4 slots,
    # ragged prompts and budgets long enough that admission-time block
    # demand overlaps decode residency (this is what forces preemption
    # at pool_blocks=8 — the _COMMON stream's short budgets drain too
    # fast to collide).  The protected class arrives LAST (worst case
    # for FIFO) and must still complete in full.
    srng = np.random.default_rng(3)
    sreqs, sprompts = [], {}
    for i in range(10):
        plen = int(srng.integers(6, 30))
        sprompts[i] = srng.integers(0, 64, size=(plen,)).astype(np.int32)
        sreqs.append(Request(i, plen, int(srng.integers(6, 14)),
                             priority=1 if i >= 6 else 0))
    # FIFO admission order on both sides (clustered batching would
    # reorder admissions by traffic class and relieve the collision)
    ref = Server(CFG, ServerConfig(batch_size=4, max_seq=96,
                                   kv_compress=ccfg, prefill_chunk=8,
                                   use_clustered_batching=False,
                                   paged=PagedKVConfig(block_size=4,
                                                       pool_blocks=48)),
                 params)
    ref_out = {o.uid: o.tokens for o in ref.serve(
        [Request(r.uid, r.prompt_len, r.max_new_tokens) for r in sreqs],
        sprompts)}
    srv = Server(CFG, ServerConfig(batch_size=4, max_seq=96,
                                   kv_compress=ccfg, prefill_chunk=8,
                                   use_clustered_batching=False,
                                   paged=PagedKVConfig(block_size=4,
                                                       pool_blocks=8),
                                   # arrival-order admission: this test
                                   # pins the preempt/swap/resume path,
                                   # which priority-first ordering would
                                   # mostly sidestep
                                   scheduler=SLOConfig(
                                       priority_admission=False),
                                   mesh=mesh),
                 params)
    outs = srv.serve(sreqs, sprompts)
    st = srv.last_stats
    assert st["sched_preemptions"] >= 1.0
    assert st["sched_shed_high"] == 0.0
    assert st["sched_backlog_end"] == 0.0
    for o in outs:
        if o.shed:
            assert sreqs[o.uid].priority == 0
            continue
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    done = {o.uid for o in outs if not o.shed}
    assert all(r.uid in done for r in sreqs if r.priority == 1)
    print("sharded slo preemption parity OK")
    """)


@pytest.mark.slow
def test_sharded_moe_expert_placement_parity():
    """Qwen2-MoE serving on a 2x4 mesh with routed-expert banks
    DISTRIBUTED on the model axis (serving_param_specs) instead of
    replicated: greedy tokens bit-identical to the single-device engine,
    blocking and chunked admission, and the expert leaves really are
    sharded (pure param placement — no cache change)."""
    run_sub(_COMMON + """
    from repro import configs
    from repro.sharding.rules import _key_str
    cfg = configs.get_reduced("qwen2-moe-a2.7b")
    p = tfm.init_params(jax.random.PRNGKey(0), cfg)
    ref = Server(cfg, ServerConfig(batch_size=4, max_seq=64), p)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    srv = Server(cfg, ServerConfig(batch_size=4, max_seq=64, mesh=mesh), p)
    for o in srv.serve(reqs, prompts):
        assert o.tokens == ref_out[o.uid], o.uid
    # the expert banks are distributed, everything else replicated
    flat, _ = jax.tree_util.tree_flatten_with_path(srv.params)
    expert_specs, other_specs = [], []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        spec = leaf.sharding.spec
        if path.endswith(("moe/w_gate", "moe/w_up", "moe/w_down")) \
                and "shared" not in path:
            expert_specs.append((path, spec))
        else:
            other_specs.append((path, spec))
    assert expert_specs, "no expert leaves found"
    for path, spec in expert_specs:
        flat_axes = [a for s in spec if s for a in
                     ((s,) if isinstance(s, str) else s)]
        assert "model" in flat_axes, (path, spec)
    for path, spec in other_specs:
        assert all(s is None for s in spec), (path, spec)

    # chunked admission with the distributed placement stays identical
    refc = Server(cfg, ServerConfig(batch_size=4, max_seq=64,
                                    prefill_chunk=8), p)
    refc_out = {o.uid: o.tokens for o in refc.serve(reqs, prompts)}
    srvc = Server(cfg, ServerConfig(batch_size=4, max_seq=64,
                                    prefill_chunk=8, mesh=mesh), p)
    for o in srvc.serve(reqs, prompts):
        assert o.tokens == refc_out[o.uid], o.uid
    print("sharded moe expert placement parity OK")
    """)


def test_serving_param_specs_single_device():
    """Placement rules need no devices: routed-expert banks take the
    model axis (spilling to data when the count divides, prefix-falling
    back to model alone for Qwen2's 60), scan-stacked leading dims stay
    unsharded, and every non-expert leaf replicates."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import Rules, default_table, serving_param_specs

    class FakeMesh:
        shape = {"data": 2, "model": 4}
        axis_names = ("data", "model")

    rules = Rules(FakeMesh(), default_table(False))
    import numpy as np
    params = {
        "tail": [{"moe": {
            "w_gate": np.zeros((8, 64, 96)),      # 8 % 8 == 0 → model×data
            "w_down": np.zeros((60, 96, 64)),     # 60 % 8 != 0 → model only
            "router": np.zeros((64, 8)),
            "shared": {"w_gate": np.zeros((64, 128))},
        }, "wq": np.zeros((64, 64))}],
        "scan": {"moe": {"w_up": np.zeros((2, 8, 64, 96))}},
    }
    specs = serving_param_specs(params, rules)
    t = specs["tail"][0]
    assert t["moe"]["w_gate"] == P(("model", "data"), None, None)
    assert t["moe"]["w_down"] == P(("model",), None, None)
    assert specs["scan"]["moe"]["w_up"] == P(None, ("model", "data"),
                                             None, None)
    # replicated at serve time even though train-time rules shard them
    assert t["moe"]["router"] == P()
    assert t["moe"]["shared"]["w_gate"] == P()
    assert t["wq"] == P()


@pytest.mark.slow
def test_sharded_recurrent_parity():
    """Recurrent-state families on a 2x4 mesh (the layer-state exit
    pin): mamba2-style 'GM' and RG-LRU 'GR' configs serve chunked dense
    AND chunked paged with greedy tokens bit-identical to a blocking
    one-request-at-a-time single-device decode.  Recurrent leaves shard
    slot-only over the data axis; the mixed prefill+decode launch
    advances them inside the same shard_map island as the ring KV."""
    run_sub(_COMMON + """
    from repro.models.config import SSMConfig
    from repro.runtime.kv_pool import PagedKVConfig
    GM = ModelConfig(name="gm4", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="GM",
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                   head_dim=32, n_groups=1, chunk=32))
    GR = ModelConfig(name="gr4", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="GR", lru_width=64)
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    rreqs = [Request(i, int(l), g) for i, (l, g) in enumerate(
        [(60, 12), (9, 10), (48, 9), (21, 14)])]
    rprompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in rreqs}
    for name, cfg in (("GM", GM), ("GR", GR)):
        p = tfm.init_params(jax.random.PRNGKey(0), cfg)
        ref = Server(cfg, ServerConfig(batch_size=1, max_seq=96,
                                       engine="static",
                                       use_clustered_batching=False), p)
        ref_out = {o.uid: o.tokens for o in ref.serve(rreqs, rprompts)}
        dense = Server(cfg, ServerConfig(batch_size=4, max_seq=96,
                                         kv_compress=ccfg, prefill_chunk=8,
                                         mesh=mesh), p)
        for o in dense.serve(rreqs, rprompts):
            assert o.tokens == ref_out[o.uid], (name, "dense", o.uid)
        srv = Server(cfg, ServerConfig(batch_size=4, max_seq=96,
                                       kv_compress=ccfg, prefill_chunk=8,
                                       paged=PagedKVConfig(block_size=4),
                                       mesh=mesh), p)
        for o in srv.serve(rreqs, rprompts):
            assert o.tokens == ref_out[o.uid], (name, "paged", o.uid)
        st = srv.last_stats
        assert st["state_bytes_recurrent"] > 0
        assert st["kv_retired_recurrent"] == 0.0
        assert st["pool_blocks_end"] == 0.0
    print("sharded recurrent parity OK")
    """)


@pytest.mark.slow
def test_sharded_recurrent_preemption_parity():
    """Preempt→swap→resume through recurrent state on a 2x4 mesh: the
    slot snapshot carries the (conv, ssm)/(conv, h) leaves across the
    host round-trip, and every non-shed request finishes bit-identical
    to an unpressured serve.  Completes the layer-state exit pin."""
    run_sub(_COMMON + """
    from repro.models.config import SSMConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.scheduler import SLOConfig
    GM = ModelConfig(name="gm4", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="GM",
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                   head_dim=32, n_groups=1, chunk=32))
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    srng = np.random.default_rng(3)
    sreqs, sprompts = [], {}
    for i in range(10):
        plen = int(srng.integers(6, 30))
        sprompts[i] = srng.integers(0, 64, size=(plen,)).astype(np.int32)
        sreqs.append(Request(i, plen, int(srng.integers(6, 14)),
                             priority=1 if i >= 6 else 0))
    p = tfm.init_params(jax.random.PRNGKey(0), GM)
    ref = Server(GM, ServerConfig(batch_size=4, max_seq=96,
                                  kv_compress=ccfg, prefill_chunk=8,
                                  use_clustered_batching=False,
                                  paged=PagedKVConfig(block_size=4,
                                                      pool_blocks=48)), p)
    ref_out = {o.uid: o.tokens for o in ref.serve(
        [Request(r.uid, r.prompt_len, r.max_new_tokens) for r in sreqs],
        sprompts)}
    srv = Server(GM, ServerConfig(batch_size=4, max_seq=96,
                                  kv_compress=ccfg, prefill_chunk=8,
                                  use_clustered_batching=False,
                                  paged=PagedKVConfig(block_size=4,
                                                      pool_blocks=8),
                                  scheduler=SLOConfig(
                                      priority_admission=False),
                                  mesh=mesh), p)
    outs = srv.serve(sreqs, sprompts)
    st = srv.last_stats
    assert st["sched_preemptions"] >= 1.0
    assert st["sched_swaps_in"] >= 1.0
    assert st["sched_shed_high"] == 0.0
    assert st["sched_swap_bytes"] == 0.0
    for o in outs:
        if o.shed:
            assert sreqs[o.uid].priority == 0
            continue
        assert o.tokens == ref_out[o.uid], (o.uid, o.tokens, ref_out[o.uid])
    done = {o.uid for o in outs if not o.shed}
    assert all(r.uid in done for r in sreqs if r.priority == 1)
    print("sharded recurrent preemption parity OK")
    """)


@pytest.mark.slow
def test_indivisible_heads_fall_back_to_replication():
    """A model whose kv-head count doesn't divide the model axis must
    still serve correctly (heads replicate, slots stay data-sharded)."""
    run_sub(_COMMON + """
    cfg2 = ModelConfig(name="tiny2", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                       vocab=64, pad_vocab_multiple=16, dtype="float32")
    p2 = tfm.init_params(jax.random.PRNGKey(1), cfg2)
    ref = Server(cfg2, ServerConfig(batch_size=4, max_seq=64), p2)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    srv = Server(cfg2, ServerConfig(batch_size=4, max_seq=64, mesh=mesh), p2)
    for o in srv.serve(reqs, prompts):
        assert o.tokens == ref_out[o.uid], o.uid
    print("indivisible-head fallback OK")
    """)


def test_cache_partition_specs_single_device():
    """Spec derivation needs no devices: slots→data, kv heads→model,
    scan-stacked leaves shift by the layer dim, indivisible dims
    replicate."""
    import jax
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding import Rules, cache_spec, default_table

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # pretend-shape table: axes_for checks divisibility against mesh shape
    # (1, 1) → everything divides; the point here is axis placement
    rules = Rules(mesh, default_table(False))
    assert cache_spec("tail/0/k", (4, 64, 2, 16), rules) == \
        P(("data",), None, ("model",), None)
    assert cache_spec("scan/sub0/k_cents", (2, 4, 8, 2, 16), rules) == \
        P(None, ("data",), None, ("model",), None)
    assert cache_spec("scan/sub0/counts", (2, 4, 8, 2), rules) == \
        P(None, ("data",), None, ("model",))
    assert cache_spec("tail/0/cov", (4,), rules) == P(("data",))
    assert cache_spec("tail/0/k_scale", (2,), rules) == P(("model",))
    # paged pool leaves: block axis over data (pool sized shards ×
    # pool_blocks, contiguous partition = shard-local block ids), heads
    # over model; block tables follow slots with columns replicated
    from repro.sharding import block_table_spec
    assert cache_spec("tail/0/k_tail", (8, 4, 2, 16), rules) == \
        P(("data",), None, ("model",), None)
    assert cache_spec("scan/sub0/v_tail", (2, 8, 4, 2, 16), rules) == \
        P(None, ("data",), None, ("model",), None)
    assert block_table_spec((4, 4), rules) == P(("data",), None)
    # sliding-window 'L' rings are dense window-sized rings (never
    # pool-backed — WindowRetention retires virtually, the ring
    # overwrite reclaims storage) and place exactly like exact-KV rings
    assert cache_spec("tail/1/k", (4, 16, 2, 16), rules) == \
        P(("data",), None, ("model",), None)
    # MLA latents / SSM state: slot sharding only
    assert cache_spec("tail/0/ckv", (4, 64, 8), rules) == \
        P(("data",), None, None)
    assert cache_spec("scan/sub0/ssm", (2, 4, 2, 16, 16), rules) == \
        P(None, ("data",), None, None, None)


def test_indivisible_dims_replicate_in_specs():
    import jax
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding import Rules, cache_spec, default_table

    # model axis of size 1 but batch 3 on a data axis of 1: always divides;
    # emulate indivisibility via the table against a fake 2-wide mesh shape
    class FakeMesh:
        shape = {"data": 2, "model": 4}
        axis_names = ("data", "model")

    rules = Rules(FakeMesh(), default_table(False))
    # 3 slots don't divide data=2 → replicated; 2 kv heads don't divide
    # model=4 → replicated
    assert cache_spec("tail/0/k", (3, 64, 2, 16), rules) == \
        P(None, None, None, None)
    # 4 slots divide, 8 heads divide
    assert cache_spec("tail/0/k", (4, 64, 8, 16), rules) == \
        P(("data",), None, ("model",), None)
