"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracles,
swept across shapes and dtypes per the deliverable requirements."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizer
from repro.kernels import ops, ref
from repro.kernels.bitserial_median import grouped_median_pallas
from repro.kernels.distance_argmin import distance_argmin_pallas


def _to_u(ints):
    return quantizer.to_unsigned_order(jnp.asarray(ints, jnp.int32))


class TestBitserialMedianKernel:
    @pytest.mark.parametrize("n,d,k", [
        (5, 1, 1), (8, 3, 2), (33, 7, 4), (64, 130, 3), (100, 12, 16),
    ])
    def test_sweep_shapes(self, n, d, k):
        rng = np.random.default_rng(n * d * k)
        x = rng.integers(-(2**20), 2**20, size=(n, d)).astype(np.int32)
        assign = rng.integers(0, k, size=(n,)).astype(np.int32)
        w = np.ones((n,), np.float32)
        med_u = grouped_median_pallas(_to_u(x), jnp.asarray(assign),
                                      jnp.asarray(w), k, interpret=True)
        med = np.asarray(quantizer.from_unsigned_order(med_u))
        expect, counts = ref.grouped_median_ref(x, assign, k)
        for c in range(k):
            if counts[c] > 0:
                np.testing.assert_array_equal(med[c], expect[c],
                                              err_msg=f"cluster {c}")

    @pytest.mark.parametrize("bits", [16, 32])
    def test_bit_widths(self, bits):
        rng = np.random.default_rng(bits)
        lim = 2 ** (bits - 2)
        x = rng.integers(-lim, lim, size=(17, 4)).astype(np.int32)
        assign = rng.integers(0, 3, size=(17,)).astype(np.int32)
        w = np.ones((17,), np.float32)
        u = quantizer.to_unsigned_order(jnp.asarray(x), bits=bits)
        med_u = grouped_median_pallas(u, jnp.asarray(assign),
                                      jnp.asarray(w), 3, bits=bits,
                                      interpret=True)
        med = np.asarray(quantizer.from_unsigned_order(med_u, bits=bits))
        expect, counts = ref.grouped_median_ref(x, assign, 3)
        for c in range(3):
            if counts[c] > 0:
                np.testing.assert_array_equal(med[c], expect[c])

    def test_weighted(self):
        rng = np.random.default_rng(7)
        x = rng.integers(-50, 50, size=(12, 5)).astype(np.int32)
        w = rng.integers(1, 4, size=(12,)).astype(np.float32)
        assign = np.zeros((12,), np.int32)
        med_u = grouped_median_pallas(_to_u(x), jnp.asarray(assign),
                                      jnp.asarray(w), 1, interpret=True)
        med = np.asarray(quantizer.from_unsigned_order(med_u))
        expect = ref.weighted_lower_median_ref(x.astype(np.float64), w)
        np.testing.assert_array_equal(med[0].astype(np.float64), expect)

    def test_matches_pure_jax_path(self):
        # ops-level consistency: kernel path == reduction-tree fallback path
        from repro.core import bitserial
        rng = np.random.default_rng(11)
        x = rng.integers(-(2**10), 2**10, size=(40, 9)).astype(np.int32)
        assign = rng.integers(0, 5, size=(40,)).astype(np.int32)
        u = _to_u(x)
        med_k, tot_k = ops.grouped_median_bits(u, jnp.asarray(assign), 5,
                                               interpret=True)
        med_j, tot_j = bitserial.grouped_median_bits(u, jnp.asarray(assign), 5)
        np.testing.assert_array_equal(np.asarray(med_k), np.asarray(med_j))
        np.testing.assert_allclose(np.asarray(tot_k), np.asarray(tot_j))


class TestDistanceArgminKernel:
    @pytest.mark.parametrize("metric", ["l1", "l2"])
    @pytest.mark.parametrize("n,d,k", [
        (7, 2, 2), (32, 12, 5), (100, 3, 16), (257, 8, 4),
    ])
    def test_sweep(self, metric, n, d, k):
        rng = np.random.default_rng(n + d + k)
        x = rng.normal(size=(n, d)).astype(np.float32)
        c = rng.normal(size=(k, d)).astype(np.float32)
        a, m = distance_argmin_pallas(jnp.asarray(x), jnp.asarray(c),
                                      metric=metric, n_block=64,
                                      interpret=True)
        ea, em = ref.distance_argmin_ref(x, c, metric)
        np.testing.assert_array_equal(np.asarray(a), ea)
        np.testing.assert_allclose(np.asarray(m), em, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(16, 4)).astype(dtype)
        c = rng.normal(size=(3, 4)).astype(dtype)
        a, m = distance_argmin_pallas(jnp.asarray(x), jnp.asarray(c),
                                      metric="l2", n_block=16, interpret=True)
        ea, _ = ref.distance_argmin_ref(x.astype(np.float32),
                                        c.astype(np.float32), "l2")
        np.testing.assert_array_equal(np.asarray(a), ea)

    def test_tie_takes_first(self):
        x = np.zeros((4, 2), np.float32)
        c = np.zeros((3, 2), np.float32)  # all centroids identical
        a, _ = distance_argmin_pallas(jnp.asarray(x), jnp.asarray(c),
                                      metric="l1", n_block=4, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.zeros((4,), np.int32))


class TestFlashDecodeKernel:
    @pytest.mark.parametrize("b,s,hq,hkv,dh,t", [
        (1, 64, 4, 2, 16, 64), (2, 128, 8, 2, 32, 100),
        (1, 96, 4, 4, 16, 1), (2, 64, 4, 1, 8, 33),
    ])
    def test_matches_decode_attention(self, b, s, hq, hkv, dh, t):
        from repro.kernels.flash_decode import flash_decode_pallas
        from repro.models.attention import decode_attention
        rng = np.random.default_rng(b + s + t)
        q = jnp.asarray(rng.normal(size=(b, hq, dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype(np.float32))
        got = flash_decode_pallas(q, k, v, jnp.int32(t), scale=dh**-0.5,
                                  chunk=32, interpret=True)
        want = decode_attention(q, k, v, t=t, scale=dh**-0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_softcap_path(self):
        from repro.kernels.flash_decode import flash_decode_pallas
        from repro.models.attention import decode_attention
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 4, 16)).astype(np.float32)) * 4
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
        got = flash_decode_pallas(q, k, v, jnp.int32(50), scale=0.25,
                                  softcap=20.0, chunk=16, interpret=True)
        want = decode_attention(q, k, v, t=50, scale=0.25, softcap=20.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


class TestInterpretFallback:
    """``ops.interpret_default`` is the single backend-detection point for
    every Pallas wrapper; on the CPU backend it must flip all of them into
    interpret mode (a Mosaic attempt would fail outright here)."""

    def test_detects_cpu(self):
        assert jax.default_backend() != "tpu"  # this container's contract
        assert ops.interpret_default() is True

    def test_clustered_decode_resolves_none_via_helper(self):
        """interpret=None (the default) must run on CPU — i.e. the kernel
        module resolved it through the shared helper — and match an
        explicit interpret=True call bit-for-bit."""
        from repro.kernels.clustered_decode import clustered_decode_pallas
        rng = np.random.default_rng(3)
        b, c, r, hq, hkv, dh = 2, 4, 8, 4, 2, 16
        args = (
            jnp.asarray(rng.normal(size=(b, hq, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, c, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, c, hkv, dh)), jnp.float32),
            jnp.asarray(rng.uniform(1, 4, size=(b, c, hkv)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, r, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, r, hkv, dh)), jnp.float32),
            jnp.asarray([6, 7], jnp.int32),
            jnp.asarray([2, 3], jnp.int32),
        )
        auto = clustered_decode_pallas(*args, scale=dh**-0.5)
        explicit = clustered_decode_pallas(*args, scale=dh**-0.5,
                                           interpret=True)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))

    def test_ops_wrapper_uses_fallback_on_cpu(self):
        """The jitted ops.clustered_decode path (interpret resolved by the
        helper) executes on CPU and matches the direct kernel call."""
        from repro.kernels.clustered_decode import clustered_decode_pallas
        rng = np.random.default_rng(4)
        b, c, r, hq, hkv, dh = 1, 4, 8, 2, 1, 8
        args = (
            jnp.asarray(rng.normal(size=(b, hq, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, c, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, c, hkv, dh)), jnp.float32),
            jnp.asarray(rng.uniform(1, 4, size=(b, c, hkv)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, r, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, r, hkv, dh)), jnp.float32),
            jnp.asarray([5], jnp.int32),
            jnp.asarray([1], jnp.int32),
        )
        got = ops.clustered_decode(*args, scale=dh**-0.5)
        want = clustered_decode_pallas(*args, scale=dh**-0.5, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
