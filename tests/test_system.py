"""End-to-end system behaviour tests.

  * training loop: loss decreases on the synthetic corpus,
  * fault tolerance: a mid-run crash + restart resumes from the last
    committed checkpoint and reproduces the uninterrupted run exactly
    (deterministic data pipeline + deterministic update),
  * serving: request-clustered batching produces well-formed completions,
  * paper pipeline: k-medians clustering on the paper-style table with
    recognition-rate evaluation.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering
from repro.core.clustering import ClusterConfig
from repro.core.request_cluster import Request
from repro.data import pipeline
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.server import Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")


def make_pieces(tmpdir, n_steps, fail_at=None, seed=7):
    dc = pipeline.DataConfig(seed=seed, global_batch=8, seq_len=32)
    data = pipeline.SyntheticLM(TINY, dc)
    aw = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=n_steps,
                           weight_decay=0.01)

    def loss_fn(params, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return tfm.train_loss(params, TINY, b, remat=False)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(grads, opt_state, params, aw)
        return params, opt_state, dict(metrics, loss=loss, **om)

    tcfg = TrainerConfig(n_steps=n_steps, ckpt_dir=str(tmpdir),
                         ckpt_every=10, log_every=100, fail_at_step=fail_at)
    return Trainer(TINY, tcfg, aw, step_fn, data)


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        tr = make_pieces(tmp_path / "a", 30)
        tr.run()
        first = np.mean(tr.losses[:5])
        last = np.mean(tr.losses[-5:])
        assert last < first - 0.2, (first, last)

    def test_crash_resume_reproduces_uninterrupted_run(self, tmp_path):
        # clean run
        tr_clean = make_pieces(tmp_path / "clean", 25)
        p_clean, _ = tr_clean.run()

        # crashing run: dies at step 17 (after ckpt at 10)
        tr_crash = make_pieces(tmp_path / "crash", 25, fail_at=17)
        with pytest.raises(RuntimeError, match="injected failure"):
            tr_crash.run()

        # restart: resumes from step 10 and completes
        tr_resume = make_pieces(tmp_path / "crash", 25)
        p_resumed, _ = tr_resume.run()

        for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_resumed)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-5)


class TestServing:
    def test_serve_clustered_batches(self):
        params = tfm.init_params(jax.random.PRNGKey(0), TINY)
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64), params)
        rng = np.random.default_rng(0)
        reqs = [Request(i, int(l), 4) for i, l in
                enumerate([5, 6, 20, 22, 5, 21])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == list(range(6))
        for o in outs:
            assert len(o.tokens) == 4
            assert all(0 <= t < TINY.padded_vocab for t in o.tokens)


class TestPaperPipeline:
    def test_kmedians_on_wine_like_table(self):
        x, y = pipeline.wine_like(n=600, seed=0)
        xs = (x - x.mean(0)) / (x.std(0) + 1e-6)
        cfg = ClusterConfig(k=3, centroid="median", metric="l1", seed=1)
        res = clustering.fit(jnp.asarray(xs), cfg)
        rate = clustering.recognition_rate(res.assign, jnp.asarray(y), 3, 3)
        assert float(rate) > 0.6, float(rate)

    def test_median_beats_mean_with_outliers(self):
        x, y = pipeline.census_like(n=1000, seed=2, outlier_frac=0.05)
        xs = jnp.asarray(x)
        med = clustering.fit(xs, ClusterConfig(k=5, centroid="median",
                                               metric="l1", seed=3))
        mean = clustering.fit(xs, ClusterConfig(k=5, centroid="mean",
                                                metric="l2", seed=3))
        r_med = float(clustering.recognition_rate(med.assign, jnp.asarray(y),
                                                  5, 5))
        r_mean = float(clustering.recognition_rate(mean.assign,
                                                   jnp.asarray(y), 5, 5))
        assert r_med >= r_mean - 0.02, (r_med, r_mean)
