"""Unit + property tests for the bit-serial median engine (pure JAX path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitserial, quantizer
from repro.kernels import ref


def _to_u(ints):
    q = jnp.asarray(ints, jnp.int32)
    return quantizer.to_unsigned_order(q)


class TestMedianBits:
    @pytest.mark.parametrize("n", [1, 2, 3, 5, 16, 17, 101])
    def test_matches_sort_oracle(self, n):
        rng = np.random.default_rng(n)
        x = rng.integers(-(2**20), 2**20, size=(n, 7)).astype(np.int32)
        med_u = bitserial.median_bits(_to_u(x))
        med = quantizer.from_unsigned_order(med_u)
        np.testing.assert_array_equal(np.asarray(med),
                                      ref.lower_median_ref(x, axis=0))

    def test_negative_values(self):
        x = np.array([[-5], [-1], [3]], np.int32)
        med = quantizer.from_unsigned_order(bitserial.median_bits(_to_u(x)))
        assert int(med[0]) == -1

    def test_weighted_matches_repetition(self):
        rng = np.random.default_rng(0)
        x = rng.integers(-100, 100, size=(9, 4)).astype(np.int32)
        w = rng.integers(0, 5, size=(9,)).astype(np.int32)
        if w.sum() == 0:
            w[0] = 1
        med_u = bitserial.median_bits(_to_u(x), weights=jnp.asarray(w)[:, None])
        med = quantizer.from_unsigned_order(med_u)
        expect = ref.weighted_lower_median_ref(x.astype(np.float64), w)
        np.testing.assert_array_equal(np.asarray(med, np.float64), expect)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(-(2**30), 2**30 - 1), min_size=1, max_size=64))
    def test_property_lower_median(self, vals):
        x = np.asarray(vals, np.int32)[:, None]
        med = quantizer.from_unsigned_order(bitserial.median_bits(_to_u(x)))
        assert int(med[0]) == int(ref.lower_median_ref(x, axis=0)[0])

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(-(2**30), 2**30 - 1), min_size=3, max_size=32),
           st.randoms(use_true_random=False))
    def test_property_permutation_invariant(self, vals, rnd):
        x = np.asarray(vals, np.int32)
        perm = list(range(len(x)))
        rnd.shuffle(perm)
        m1 = bitserial.median_bits(_to_u(x[:, None]))
        m2 = bitserial.median_bits(_to_u(x[perm][:, None]))
        assert int(m1[0]) == int(m2[0])


class TestMedianFloat:
    def test_float_median_quantized_grid(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(51, 6)).astype(np.float32) * 10.0
        med = bitserial.median(jnp.asarray(x), bits=32)
        expect = ref.lower_median_ref(x, axis=0)
        scale = np.asarray(quantizer.auto_scale(jnp.asarray(x), 32))
        np.testing.assert_allclose(np.asarray(med), expect, atol=1.0 / scale.min())

    def test_bits16(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(33, 3)).astype(np.float32)
        med = bitserial.median(jnp.asarray(x), bits=16)
        expect = ref.lower_median_ref(x, axis=0)
        np.testing.assert_allclose(np.asarray(med), expect, atol=2e-3)


class TestMedian64:
    def test_two_limb_matches_oracle(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(21, 4)).astype(np.float64) * 1e3
        scale = 2.0**20
        hi, lo = quantizer.quantize64_host(x, scale)
        mh, ml = bitserial.median_bits64(jnp.asarray(hi), jnp.asarray(lo))
        got = quantizer.dequantize64_host(np.asarray(mh), np.asarray(ml), scale)
        expect = ref.lower_median_ref(np.round(x * scale) / scale, axis=0)
        np.testing.assert_allclose(got, expect, atol=1.0 / scale)


class TestGroupedMedian:
    @pytest.mark.parametrize("n,d,k", [(10, 3, 2), (64, 5, 4), (101, 2, 7)])
    def test_matches_grouped_oracle(self, n, d, k):
        rng = np.random.default_rng(n * k)
        x = rng.integers(-(2**16), 2**16, size=(n, d)).astype(np.int32)
        assign = rng.integers(0, k, size=(n,)).astype(np.int32)
        med_u, totals = bitserial.grouped_median_bits(
            _to_u(x), jnp.asarray(assign), k)
        med = np.asarray(quantizer.from_unsigned_order(med_u))
        expect, counts = ref.grouped_median_ref(x, assign, k)
        for c in range(k):
            if counts[c] > 0:
                np.testing.assert_array_equal(med[c], expect[c])
        np.testing.assert_array_equal(np.asarray(totals), counts.astype(np.float32))

    def test_empty_cluster_total_zero(self):
        x = np.array([[1, 2], [3, 4]], np.int32)
        assign = np.array([0, 0], np.int32)
        _, totals = bitserial.grouped_median_bits(_to_u(x), jnp.asarray(assign), 3)
        assert float(totals[1]) == 0.0 and float(totals[2]) == 0.0

    def test_jit_and_grad_free(self):
        # jit-compiles cleanly (dry smoke)
        f = jax.jit(lambda u, a: bitserial.grouped_median_bits(u, a, 4))
        u = _to_u(np.arange(32, dtype=np.int32).reshape(8, 4))
        a = jnp.asarray(np.arange(8, dtype=np.int32) % 4)
        med, tot = f(u, a)
        assert med.shape == (4, 4) and tot.shape == (4,)
