"""Distributed reduction-tree tests.

These run in a subprocess with XLA_FLAGS forcing 8 host devices (the main
test process must keep the default single device, per the dry-run contract),
and verify that the shard_map median/clustering path — per-bit psum of vote
counts, the paper's interconnection reduction tree — matches the
single-device result exactly.
"""

import pytest

from _subproc import run_sub


@pytest.mark.slow
def test_distributed_median_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.5: experimental namespace
            from jax.experimental.shard_map import shard_map
        from repro.core import bitserial, quantizer

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(0)
        x = rng.integers(-2**20, 2**20, size=(128, 16)).astype(np.int32)
        assign = rng.integers(0, 4, size=(128,)).astype(np.int32)
        u = quantizer.to_unsigned_order(jnp.asarray(x))

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        f = shard_map(
            lambda uu, aa: bitserial.grouped_median_bits(uu, aa, 4,
                                                         axis_name="data"),
            mesh=mesh,
            in_specs=(P("data", None), P("data")),
            out_specs=(P(), P()),
        )
        med_d, tot_d = jax.jit(f)(u, jnp.asarray(assign))
        med_s, tot_s = bitserial.grouped_median_bits(u, jnp.asarray(assign), 4)
        np.testing.assert_array_equal(np.asarray(med_d), np.asarray(med_s))
        np.testing.assert_allclose(np.asarray(tot_d), np.asarray(tot_s))
        print("distributed median OK")
    """)


@pytest.mark.slow
def test_distributed_kmedians_fit_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.5: experimental namespace
            from jax.experimental.shard_map import shard_map
        from repro.core import clustering
        from repro.core.clustering import ClusterConfig

        rng = np.random.default_rng(1)
        centers = np.array([[0,0],[6,6],[-6,6]], np.float32)
        xs = np.concatenate([
            rng.normal(size=(64, 2)).astype(np.float32)*0.3 + c
            for c in centers])
        perm = rng.permutation(len(xs)); xs = xs[perm]
        x = jnp.asarray(xs)
        cfg = ClusterConfig(k=3, centroid="median", metric="l1", max_iters=20)
        init = x[:3]

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        fit_d = shard_map(
            lambda xx, ii: clustering.fit(xx, cfg, ii, use_kernel=False,
                                          axis_name="data"),
            mesh=mesh,
            in_specs=(P("data", None), P()),
            out_specs=clustering.ClusterResult(
                P(), P("data"), P(), P(), P()),
        )
        rd = jax.jit(fit_d)(x, init)
        rs = clustering.fit(x, cfg, init, use_kernel=False)
        np.testing.assert_allclose(np.asarray(rd.centroids),
                                   np.asarray(rs.centroids), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(rd.assign),
                                      np.asarray(rs.assign))
        print("distributed k-medians OK")
    """)


@pytest.mark.slow
def test_distributed_weighted_compress_head_matches_single_device():
    """kv_compress.compress_head(axis_name=...) — the psum-consistent
    weighted k-medians used when recompaction points span a mesh axis —
    must produce the single-device centroids/value-sums/counts exactly
    (per-bit vote psum + value/count psum, warm-started init)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.5: experimental namespace
            from jax.experimental.shard_map import shard_map
        from repro.core import kv_compress

        rng = np.random.default_rng(2)
        S, Dh, C = 128, 16, 8
        keys = jnp.asarray(rng.normal(size=(S, Dh)), jnp.float32)
        vals = jnp.asarray(rng.normal(size=(S, Dh)), jnp.float32)
        # mixed weights: masked rows (0) and pre-aggregated summaries (>1)
        w = jnp.asarray(((rng.random(S) < 0.8)
                         * rng.integers(1, 4, size=S)).astype(np.float32))
        cfg = kv_compress.KVCompressConfig(n_clusters=C, iters=6,
                                           keep_recent=16)
        init = keys[:C]

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("model",))
        f = shard_map(
            lambda kk, vv, ww, ii: kv_compress.compress_head(
                kk, vv, cfg, weights=ww, init_centroids=ii,
                axis_name="model"),
            mesh=mesh,
            in_specs=(P("model", None), P("model", None), P("model"), P()),
            out_specs=(P(), P(), P()),
        )
        kc_d, vc_d, cnt_d = jax.jit(f)(keys, vals, w, init)
        kc_s, vc_s, cnt_s = kv_compress.compress_head(
            keys, vals, cfg, weights=w, init_centroids=init)
        np.testing.assert_allclose(np.asarray(kc_d), np.asarray(kc_s),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(vc_d), np.asarray(vc_s),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cnt_d), np.asarray(cnt_s),
                                   rtol=1e-5)
        print("distributed weighted compress_head OK")
    """)


@pytest.mark.slow
def test_elastic_restore_onto_sharded_mesh(tmp_path):
    """Checkpoint written by a 1-host run restores onto an 8-device mesh
    with NamedShardings (elastic restart across topologies)."""
    import jax, numpy as np
    from repro.checkpoint import ckpt
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones((16,), np.float32)}
    ckpt.save(str(tmp_path), 5, tree)
    run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt

        assert len(jax.devices()) == 8
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        like = {{"w": jnp.zeros((8, 8)), "b": jnp.zeros((16,))}}
        sh = {{"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P()) }}
        tree, step = ckpt.restore({str(tmp_path)!r}, like, shardings=sh)
        assert step == 5
        assert tree["w"].sharding.spec == P("data", None)
        np.testing.assert_array_equal(
            np.asarray(tree["w"]), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("elastic restore OK")
    """)
