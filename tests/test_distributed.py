"""Distributed reduction-tree tests.

These run in a subprocess with XLA_FLAGS forcing 8 host devices (the main
test process must keep the default single device, per the dry-run contract),
and verify that the shard_map median/clustering path — per-bit psum of vote
counts, the paper's interconnection reduction tree — matches the
single-device result exactly.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_distributed_median_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.5: experimental namespace
            from jax.experimental.shard_map import shard_map
        from repro.core import bitserial, quantizer

        assert len(jax.devices()) == 8
        rng = np.random.default_rng(0)
        x = rng.integers(-2**20, 2**20, size=(128, 16)).astype(np.int32)
        assign = rng.integers(0, 4, size=(128,)).astype(np.int32)
        u = quantizer.to_unsigned_order(jnp.asarray(x))

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        f = shard_map(
            lambda uu, aa: bitserial.grouped_median_bits(uu, aa, 4,
                                                         axis_name="data"),
            mesh=mesh,
            in_specs=(P("data", None), P("data")),
            out_specs=(P(), P()),
        )
        med_d, tot_d = jax.jit(f)(u, jnp.asarray(assign))
        med_s, tot_s = bitserial.grouped_median_bits(u, jnp.asarray(assign), 4)
        np.testing.assert_array_equal(np.asarray(med_d), np.asarray(med_s))
        np.testing.assert_allclose(np.asarray(tot_d), np.asarray(tot_s))
        print("distributed median OK")
    """)


@pytest.mark.slow
def test_distributed_kmedians_fit_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.5: experimental namespace
            from jax.experimental.shard_map import shard_map
        from repro.core import clustering
        from repro.core.clustering import ClusterConfig

        rng = np.random.default_rng(1)
        centers = np.array([[0,0],[6,6],[-6,6]], np.float32)
        xs = np.concatenate([
            rng.normal(size=(64, 2)).astype(np.float32)*0.3 + c
            for c in centers])
        perm = rng.permutation(len(xs)); xs = xs[perm]
        x = jnp.asarray(xs)
        cfg = ClusterConfig(k=3, centroid="median", metric="l1", max_iters=20)
        init = x[:3]

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        fit_d = shard_map(
            lambda xx, ii: clustering.fit(xx, cfg, ii, use_kernel=False,
                                          axis_name="data"),
            mesh=mesh,
            in_specs=(P("data", None), P()),
            out_specs=clustering.ClusterResult(
                P(), P("data"), P(), P(), P()),
        )
        rd = jax.jit(fit_d)(x, init)
        rs = clustering.fit(x, cfg, init, use_kernel=False)
        np.testing.assert_allclose(np.asarray(rd.centroids),
                                   np.asarray(rs.centroids), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(rd.assign),
                                      np.asarray(rs.assign))
        print("distributed k-medians OK")
    """)


@pytest.mark.slow
def test_elastic_restore_onto_sharded_mesh(tmp_path):
    """Checkpoint written by a 1-host run restores onto an 8-device mesh
    with NamedShardings (elastic restart across topologies)."""
    import jax, numpy as np
    from repro.checkpoint import ckpt
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones((16,), np.float32)}
    ckpt.save(str(tmp_path), 5, tree)
    run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt

        assert len(jax.devices()) == 8
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        like = {{"w": jnp.zeros((8, 8)), "b": jnp.zeros((16,))}}
        sh = {{"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P()) }}
        tree, step = ckpt.restore({str(tmp_path)!r}, like, shardings=sh)
        assert step == 5
        assert tree["w"].sharding.spec == P("data", None)
        np.testing.assert_array_equal(
            np.asarray(tree["w"]), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("elastic restore OK")
    """)
