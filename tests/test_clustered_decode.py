"""Clustered-KV decode path tests.

With empty centroids (counts=0) the clustered path must EXACTLY match
exact-cache decode while positions fit in the tail ring — pins masking,
ring indexing, and the count-bias math.  A second test fills centroids
from the paper's compressor and checks the approximation is close."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.core import kv_compress


def f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def test_empty_centroids_match_exact_within_tail():
    cfg = f32(configs.get_reduced("qwen3-4b"))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 10)), jnp.int32)

    cache_e = tfm.init_cache(cfg, 2, 32)
    cache_c = tfm.init_cache(cfg, 2, 32, kv_mode="clustered",
                             kv_clusters=8, kv_tail=16)
    step = lambda c, tk, t: tfm.decode_step(params, cfg, c, tk, t)
    for t in range(10):
        le, cache_e = step(cache_e, toks[:, t:t + 1], jnp.int32(t))
        lc, cache_c = step(cache_c, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(le), np.asarray(lc),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"t={t}")


def test_compressed_centroids_approximate_attention():
    cfg = f32(configs.get_reduced("qwen3-4b"))
    p = attn.init_attn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    b, s = 1, 96
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    # clustery keys
    centers = rng.normal(size=(6, dh)) * 2
    k = jnp.asarray(centers[rng.integers(0, 6, size=(b, s, hkv))]
                    + rng.normal(size=(b, s, hkv, dh)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)

    # exact decode attention at t = s
    q = jnp.asarray(rng.normal(size=(b, cfg.n_heads, dh)), jnp.float32)
    out_e = attn.decode_attention(q, k, v, t=s, scale=dh**-0.5)

    # compress prefix (no tail for comparability), build clustered cache
    ccfg = kv_compress.KVCompressConfig(n_clusters=12, iters=8,
                                        keep_recent=16)
    ckv = kv_compress.compress_cache(k[0], v[0], ccfg)
    cache = {
        "k_cents": ckv.k_cents.transpose(1, 0, 2)[None],   # (1, C, H, Dh)
        "v_cents": ckv.v_cents.transpose(1, 0, 2)[None],
        "counts": ckv.counts.T[None],                      # (1, C, H)
        "k_tail": jnp.zeros((b, 16, hkv, dh), jnp.float32).at[:, :16].set(
            ckv.k_tail.transpose(1, 0, 2)[None]),
        "v_tail": jnp.zeros((b, 16, hkv, dh), jnp.float32).at[:, :16].set(
            ckv.v_tail.transpose(1, 0, 2)[None]),
    }
    # clustered attention via the layer path needs x; test the math directly
    from repro.models.attention import attn_decode_clustered  # noqa: F401
    # score/combine mirror kv_compress.clustered_attention per head group:
    out_c = []
    for h in range(cfg.n_heads):
        kvh = h * hkv // cfg.n_heads
        ck = kv_compress.CompressedKV(
            k_cents=ckv.k_cents[kvh:kvh + 1], v_cents=ckv.v_cents[kvh:kvh + 1],
            counts=ckv.counts[kvh:kvh + 1], k_tail=ckv.k_tail[kvh:kvh + 1],
            v_tail=ckv.v_tail[kvh:kvh + 1])
        out_c.append(kv_compress.clustered_attention(
            q[0, h:h + 1], ck, scale=dh**-0.5))
    out_c = jnp.stack(out_c, 0)[None, :, 0]
    rel = float(jnp.linalg.norm(out_c - out_e)
                / jnp.maximum(jnp.linalg.norm(out_e), 1e-9))
    assert rel < 0.25, rel


def test_mixed_mode_kernel_matches_stepwise_decode():
    """The mixed-mode launch (prompt chunk + decode rows in one call) must
    reproduce the one-token path run L times: feeding a chunk's rows one
    at a time through the single-row kernel — writing each row into the
    ring before its own scoring — yields the same outputs as scoring the
    whole chunk in one fused call with the rows pre-written.  Pins the
    per-row position masks (intra-chunk causality via the ring) and the
    SMEM chunk_len plumbing."""
    from repro.kernels.clustered_decode import clustered_decode_pallas
    rng = np.random.default_rng(11)
    c, r, hq, hkv, dh, L = 6, 8, 4, 2, 16, 5
    # mid-stream slot, ring wrapped.  The chunk's pre-write overwrites
    # ring positions t0+i-r (the oldest live entries), so the engine
    # invariant cov >= t0 + L - r must hold — those positions are then
    # already summarized by centroids and masked from the ring either way
    t0, cov = 9, 6
    k_cents = jnp.asarray(rng.normal(size=(1, c, hkv, dh)), jnp.float32)
    v_cents = jnp.asarray(rng.normal(size=(1, c, hkv, dh)), jnp.float32)
    counts = jnp.asarray(rng.uniform(0, 3, size=(1, c, hkv)), jnp.float32)
    k_tail = jnp.asarray(rng.normal(size=(1, r, hkv, dh)), jnp.float32)
    v_tail = jnp.asarray(rng.normal(size=(1, r, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, L, hq, dh)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(L, hkv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(L, hkv, dh)), jnp.float32)

    # reference: one row at a time (write row i at slot (t0+i) % r, score)
    kt, vt = k_tail, v_tail
    want = []
    for i in range(L):
        slot = (t0 + i) % r
        kt = kt.at[:, slot].set(k_new[i][None])
        vt = vt.at[:, slot].set(v_new[i][None])
        out = clustered_decode_pallas(
            q[:, i], k_cents, v_cents, counts, kt, vt,
            jnp.asarray([t0 + i], jnp.int32), jnp.asarray([cov], jnp.int32),
            scale=dh**-0.5)
        want.append(np.asarray(out))

    # fused: all rows pre-written, one launch with chunk_len = L
    kt2, vt2 = k_tail, v_tail
    for i in range(L):
        kt2 = kt2.at[:, (t0 + i) % r].set(k_new[i][None])
        vt2 = vt2.at[:, (t0 + i) % r].set(v_new[i][None])
    got = clustered_decode_pallas(
        q, k_cents, v_cents, counts, kt2, vt2,
        jnp.asarray([t0], jnp.int32), jnp.asarray([cov], jnp.int32),
        jnp.asarray([L], jnp.int32), scale=dh**-0.5)
    for i in range(L):
        np.testing.assert_allclose(np.asarray(got)[:, i], want[i],
                                   rtol=1e-5, atol=1e-5, err_msg=f"row {i}")


def test_mixed_mode_masks_rows_past_chunk_len():
    """Rows at index >= chunk_len are garbage by contract, but rows below
    must be unaffected by their presence (mask isolation)."""
    from repro.kernels.clustered_decode import clustered_decode_pallas
    rng = np.random.default_rng(12)
    c, r, hq, hkv, dh, L = 4, 8, 2, 1, 8, 4
    args = dict(
        k_cents=jnp.asarray(rng.normal(size=(1, c, hkv, dh)), jnp.float32),
        v_cents=jnp.asarray(rng.normal(size=(1, c, hkv, dh)), jnp.float32),
        counts=jnp.asarray(rng.uniform(1, 2, size=(1, c, hkv)), jnp.float32),
        k_tail=jnp.asarray(rng.normal(size=(1, r, hkv, dh)), jnp.float32),
        v_tail=jnp.asarray(rng.normal(size=(1, r, hkv, dh)), jnp.float32))
    q = jnp.asarray(rng.normal(size=(1, L, hq, dh)), jnp.float32)
    t = jnp.asarray([5], jnp.int32)
    cov = jnp.asarray([1], jnp.int32)
    out2 = clustered_decode_pallas(q, *args.values(), t, cov,
                                   jnp.asarray([2], jnp.int32),
                                   scale=dh**-0.5)
    q_junk = q.at[:, 2:].set(999.0)      # junk beyond chunk_len
    out2b = clustered_decode_pallas(q_junk, *args.values(), t, cov,
                                    jnp.asarray([2], jnp.int32),
                                    scale=dh**-0.5)
    np.testing.assert_array_equal(np.asarray(out2)[:, :2],
                                  np.asarray(out2b)[:, :2])


def test_paged_kernel_bit_identical_to_dense():
    """The packed ragged paged kernel must reproduce the dense mixed-mode
    kernel BIT-exactly per (slot, position) row: same staged f32 tail
    operand, same dot_general contractions, same mask order — that is
    what makes the paged engine token-identical to the dense engine.
    Covers wrapped rings, a mid-flight chunk, decode rows at mixed
    depths, and fully-masked padding rows."""
    from repro.kernels.clustered_decode import clustered_decode_pallas
    from repro.kernels.paged_clustered_decode import (
        paged_clustered_decode_pallas)
    rng = np.random.default_rng(7)
    B, C, R, hq, hkv, dh, L = 4, 6, 16, 4, 2, 16, 5
    bs = 4
    T = R // bs
    k_cents = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    v_cents = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    counts = jnp.asarray(rng.uniform(0, 3, size=(B, C, hkv)), jnp.float32)
    k_tail = jnp.asarray(rng.normal(size=(B, R, hkv, dh)), jnp.float32)
    v_tail = jnp.asarray(rng.normal(size=(B, R, hkv, dh)), jnp.float32)
    t = jnp.asarray([9, 3, 30, 21], jnp.int32)      # pre/post ring wrap
    cov = jnp.asarray([6, 0, 20, 10], jnp.int32)
    cl = jnp.asarray([L, 1, 1, 1], jnp.int32)       # slot 0 admits a chunk
    q = jnp.asarray(rng.normal(size=(B, L, hq, dh)), jnp.float32)

    dense = clustered_decode_pallas(q, k_cents, v_cents, counts, k_tail,
                                    v_tail, t, cov, cl, scale=dh**-0.5)

    # paged view: identity block table, pool = the same ring bytes in
    # (nb, bs, H, Dh) blocks; pack the real rows + 2 padding rows
    k_pool = k_tail.reshape(B * T, bs, hkv, dh)
    v_pool = v_tail.reshape(B * T, bs, hkv, dh)
    bt = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T)
    rows = [(b, i) for b in range(B) for i in range(int(cl[b]))]
    n = len(rows) + 2
    row_slot = jnp.asarray([b for b, _ in rows] + [0, 0], jnp.int32)
    row_pos = jnp.asarray([int(t[b]) + i for b, i in rows] + [-1, -1],
                          jnp.int32)
    qp = jnp.concatenate([
        jnp.stack([q[b, i] for b, i in rows]),
        jnp.zeros((2, hq, dh), jnp.float32)])
    qpos1 = jnp.where(row_pos >= 0, row_pos + 1, 0)
    tw = (t + cl)[row_slot]
    got = paged_clustered_decode_pallas(
        qp, k_cents, v_cents, counts, k_pool, v_pool, row_slot,
        bt[row_slot], qpos1, tw, cov[row_slot], scale=dh**-0.5)
    assert got.shape == (n, hq, dh)
    for ri, (b, i) in enumerate(rows):
        np.testing.assert_array_equal(np.asarray(got)[ri],
                                      np.asarray(dense)[b, i],
                                      err_msg=f"row ({b},{i})")


def test_paged_kernel_window_floor_masks_like_cov():
    """The per-row retention window floor ``wlo`` (WindowRetention's
    ``t - window``) must gate ring scoring exactly like the coverage
    frontier — the kernel ANDs ``pos >= cov`` with ``pos >= wlo``, so a
    launch with (cov, wlo) is bit-identical to one with
    (max(cov, wlo), 0), and omitting ``wlo`` reproduces the pre-policy
    frontier-only masking bit-exactly."""
    from repro.kernels.paged_clustered_decode import (
        paged_clustered_decode_pallas)
    rng = np.random.default_rng(11)
    B, C, R, hq, hkv, dh = 3, 4, 16, 4, 2, 16
    bs = 4
    T = R // bs
    k_cents = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    v_cents = jnp.asarray(rng.normal(size=(B, C, hkv, dh)), jnp.float32)
    counts = jnp.asarray(rng.uniform(0, 3, size=(B, C, hkv)), jnp.float32)
    k_tail = jnp.asarray(rng.normal(size=(B, R, hkv, dh)), jnp.float32)
    v_tail = jnp.asarray(rng.normal(size=(B, R, hkv, dh)), jnp.float32)
    k_pool = k_tail.reshape(B * T, bs, hkv, dh)
    v_pool = v_tail.reshape(B * T, bs, hkv, dh)
    bt = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T)
    # decode rows pre/post ring wrap; window floors above AND below cov
    t = jnp.asarray([9, 30, 21], jnp.int32)
    cov = jnp.asarray([2, 18, 0], jnp.int32)
    wlo = jnp.asarray([5, 22, 8], jnp.int32)
    row_slot = jnp.arange(B, dtype=jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, hq, dh)), jnp.float32)
    run = lambda c, w: paged_clustered_decode_pallas(  # noqa: E731
        q, k_cents, v_cents, counts, k_pool, v_pool, row_slot, bt,
        t + 1, t + 1, c, w, scale=dh**-0.5)
    got = run(cov, wlo)
    want = run(jnp.maximum(cov, wlo), jnp.zeros_like(wlo))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the floor really engaged: every row masks more than frontier-only
    base = run(cov, jnp.zeros_like(wlo))
    assert (np.abs(np.asarray(got) - np.asarray(base)).max(axis=(1, 2))
            > 0).all(), "wlo floors masked nothing"
    # None defaults to zeros — bit-identical to the pre-policy behavior
    none = paged_clustered_decode_pallas(
        q, k_cents, v_cents, counts, k_pool, v_pool, row_slot, bt,
        t + 1, t + 1, cov, scale=dh**-0.5)
    np.testing.assert_array_equal(np.asarray(none), np.asarray(base))


def test_int8_kv_decode_close_to_bf16():
    """int8 KV cache with per-head scales ≈ exact decode (scales set from
    observed key/value ranges)."""
    cfg = f32(configs.get_reduced("qwen3-4b"))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 12)), jnp.int32)

    cache_e = tfm.init_cache(cfg, 2, 32)
    cache_q = tfm.init_cache(cfg, 2, 32, kv_mode="int8")
    # set plausible static scales (production: calibrated at prefill)
    cache_q = jax.tree.map(
        lambda l: (jnp.full(l.shape, 0.05, l.dtype)
                   if l.dtype == jnp.float32 and l.ndim == 1 else l), cache_q)
    step = lambda c, tk, t: tfm.decode_step(params, cfg, c, tk, t)
    ok = 0
    for t in range(12):
        le, cache_e = step(cache_e, toks[:, t:t + 1], jnp.int32(t))
        lq, cache_q = step(cache_q, toks[:, t:t + 1], jnp.int32(t))
        # logits drift slightly; top-1 agreement is the serving criterion
        ok += int((jnp.argmax(le, -1) == jnp.argmax(lq, -1)).all())
    assert ok >= 10, f"top-1 agreement only {ok}/12"


def test_server_compact_kv_roundtrip():
    """Server.compact_kv turns exact prefix/tail-layer caches into
    clustered ones that decode_step accepts and produces sane logits."""
    from repro.runtime.server import Server, ServerConfig
    # config with NO scan region (tail layers only) so compaction applies
    cfg = dataclasses.replace(
        configs.get_reduced("qwen3-4b"), n_layers=1, dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 96)), jnp.int32)
    logits_p, cache = tfm.prefill(params, cfg, toks, max_seq=128)

    srv = Server(cfg, ServerConfig(max_seq=128), params)
    ccfg = kv_compress.KVCompressConfig(n_clusters=12, iters=6,
                                        keep_recent=16)
    cache_c = srv.compact_kv(cache, t=96, ccfg=ccfg)
    # compacted leaves exist and shrank (single layer lives in the scan
    # region → stacked (layers, B, C, H, Dh))
    sc = cache_c["scan"]["sub0"]
    assert "k_cents" in sc and sc["k_cents"].shape[2] == 12
    assert sc["k_tail"].shape[2] == 16

    le, _ = tfm.decode_step(params, cfg, cache, toks[:, -1:], jnp.int32(96))
    lc, _ = tfm.decode_step(params, cfg, cache_c, toks[:, -1:],
                            jnp.int32(96))
    assert bool(jnp.isfinite(lc).all())
    # approximation keeps the distribution close (cosine of logits)
    cos = float(jnp.sum(le * lc)
                / (jnp.linalg.norm(le) * jnp.linalg.norm(lc)))
    assert cos > 0.98, cos
