"""Numerical parity tests between independent compute paths:

  * chunked SSD (train path)   vs sequential recurrence (decode stepping)
  * RG-LRU associative scan    vs sequential recurrence
  * prefill + decode_step      vs full forward logits (dense, local-window,
                               ssm, hybrid archs) — validates KV ring
                               buffers, caches, RoPE-at-absolute-position.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import lm_logits


def f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


class TestSSD:
    def test_chunked_matches_sequential(self):
        cfg = f32(configs.get_reduced("mamba2-2.7b"))
        p = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model),
                              jnp.float32) * 0.5
        y_chunked = ssm_mod.ssm_train(p, x, cfg)
        y_seq = ssm_mod.ssm_sequential_ref(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                                   rtol=2e-3, atol=2e-3)

    def test_chunk_size_invariance(self):
        cfg = f32(configs.get_reduced("mamba2-2.7b"))
        p = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 40, cfg.d_model),
                              jnp.float32) * 0.5
        y1 = ssm_mod.ssm_train(p, x, cfg)
        cfg2 = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
        y2 = ssm_mod.ssm_train(p, x, cfg2)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)


class TestRGLRU:
    def test_scan_matches_sequential(self):
        cfg = f32(configs.get_reduced("recurrentgemma-9b"))
        p = rg_mod.init_rglru(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                              jnp.float32) * 0.5
        y_scan = rg_mod.rglru_train(p, x, cfg)
        y_seq = rg_mod.rglru_sequential_ref(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", [
    "qwen3-4b", "gemma2-27b", "gemma3-4b", "mamba2-2.7b",
    "recurrentgemma-9b", "deepseek-v3-671b", "qwen2-moe-a2.7b",
])
def test_decode_matches_forward(arch):
    """prefill(t<P) + decode steps reproduce the full-forward logits."""
    cfg = f32(configs.get_reduced(arch))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    s, pre = 20, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, s)), jnp.int32)

    h, _ = tfm.forward_trunk(params, cfg, tokens, remat=False)
    full_logits = lm_logits(params["embed"], h, cfg)     # (1, S, V)

    logits_p, cache = tfm.prefill(params, cfg, tokens[:, :pre], max_seq=s)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, pre - 1]),
                               rtol=2e-2, atol=2e-2)

    step = jax.jit(lambda c, tk, t: tfm.decode_step(params, cfg, c, tk, t))
    for t in range(pre, s):
        logits_d, cache = step(cache, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} decode step t={t}")
