"""Substrate tests: optimizer, checkpoint (atomic commit + resume),
data pipeline determinism, gradient compression, KV compression,
request-clustering batcher."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import grad_compress, kv_compress
from repro.core.request_cluster import (Request, padding_waste, plan_batches,
                                        plan_fifo)
from repro.data import pipeline
from repro.models.config import ModelConfig
from repro.optim import adamw

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                   pad_vocab_multiple=16)


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(150):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw.update(g, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clipping_and_schedule(self):
        cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=10,
                                total_steps=100)
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0)
        assert float(adamw.schedule(cfg, jnp.int32(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)


class TestCheckpoint:
    def test_roundtrip_and_resume(self, tmp_path):
        d = str(tmp_path)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        ckpt.save(d, 3, tree)
        ckpt.save(d, 7, jax.tree.map(lambda x: x * 2, tree))
        assert ckpt.latest_step(d) == 7
        restored, step = ckpt.restore(d, tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]) * 2)

    def test_uncommitted_ignored(self, tmp_path):
        d = str(tmp_path)
        tree = {"a": jnp.zeros((2,))}
        ckpt.save(d, 1, tree)
        # simulate crash mid-save: directory without DONE
        os.makedirs(os.path.join(d, "step_00000002"))
        assert ckpt.latest_step(d) == 1

    def test_prune(self, tmp_path):
        d = str(tmp_path)
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, tree)
        ckpt.prune(d, keep=2)
        assert ckpt.latest_step(d) == 4
        assert sorted(x for x in os.listdir(d)) == ["step_00000003",
                                                    "step_00000004"]


class TestData:
    def test_deterministic_and_host_sharded(self):
        dc = pipeline.DataConfig(seed=1, global_batch=8, seq_len=32)
        ds = pipeline.SyntheticLM(TINY, dc)
        b1 = ds.batch_at(5)
        b2 = ds.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # two hosts partition the same global batch
        h0 = pipeline.SyntheticLM(
            TINY, dataclasses.replace(dc, host_id=0, n_hosts=2)).batch_at(5)
        h1 = pipeline.SyntheticLM(
            TINY, dataclasses.replace(dc, host_id=1, n_hosts=2)).batch_at(5)
        glob = np.concatenate([h0["tokens"], h1["tokens"]])
        np.testing.assert_array_equal(glob, b1["tokens"])

    def test_labels_shifted(self):
        dc = pipeline.DataConfig(seed=0, global_batch=2, seq_len=16)
        b = pipeline.SyntheticLM(TINY, dc).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestGradCompress:
    def test_roundtrip_error_small(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
        cfg = grad_compress.CompressConfig(k=16, iters=12)
        g_hat, err = grad_compress.compress_decompress(g, cfg)
        rel = float(jnp.linalg.norm(err) / jnp.linalg.norm(g))
        assert rel < 0.25, rel

    def test_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(1)
        cfg = grad_compress.CompressConfig(k=4, iters=8)
        g = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        grads = {"w": g}
        ef = grad_compress.init_ef(grads)
        acc_plain = jnp.zeros_like(g)
        acc_ef = jnp.zeros_like(g)
        for _ in range(20):
            gh, _ = grad_compress.compress_decompress(g, cfg)
            acc_plain += gh
            ghe, ef = grad_compress.apply_ef(grads, ef, cfg)
            acc_ef += ghe["w"]
        bias_plain = float(jnp.linalg.norm(acc_plain / 20 - g))
        bias_ef = float(jnp.linalg.norm(acc_ef / 20 - g))
        assert bias_ef < bias_plain * 0.5, (bias_ef, bias_plain)

    def test_wire_bytes_ratio(self):
        tree = {"w": jnp.zeros((1024, 64))}
        r = grad_compress.wire_bytes(tree, grad_compress.CompressConfig())
        assert r["ratio"] > 7.0


class TestKVCompress:
    def test_output_close_to_exact_attention(self):
        rng = np.random.default_rng(2)
        s, h, dh = 512, 2, 32
        # clustered keys (realistic: keys live on a low-dim manifold)
        centers = rng.normal(size=(8, dh)) * 2.0
        ks = (centers[rng.integers(0, 8, size=s)]
              + rng.normal(size=(s, dh)) * 0.1)
        k = jnp.asarray(np.stack([ks, ks * 0.5 + 0.1], 1), jnp.float32)
        v = jnp.asarray(rng.normal(size=(s, h, dh)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(h, dh)), jnp.float32)
        cfg = kv_compress.KVCompressConfig(n_clusters=16, iters=8,
                                           keep_recent=32)
        ckv = kv_compress.compress_cache(k, v, cfg)
        out_c = kv_compress.clustered_attention(q, ckv, scale=dh**-0.5)
        out_e = kv_compress.exact_attention(q, k, v, scale=dh**-0.5)
        err = float(jnp.linalg.norm(out_c - out_e)
                    / jnp.maximum(jnp.linalg.norm(out_e), 1e-9))
        assert err < 0.15, err

    def test_memory_ratio(self):
        cfg = kv_compress.KVCompressConfig(n_clusters=256, keep_recent=128)
        assert kv_compress.memory_ratio(32768, cfg) > 80


class TestRequestCluster:
    def test_beats_fifo_on_bimodal_lengths(self):
        rng = np.random.default_rng(3)
        reqs = []
        for i in range(64):
            if i % 2:
                reqs.append(Request(i, int(rng.integers(10, 20)), 8))
            else:
                reqs.append(Request(i, int(rng.integers(900, 1000)), 8))
        clustered = plan_batches(reqs, batch_size=8)
        fifo = plan_fifo(reqs, batch_size=8)
        assert clustered.waste < fifo.waste * 0.2, (clustered.waste,
                                                    fifo.waste)
        # every request scheduled exactly once
        seen = sorted(u for b in clustered.batches for u in b)
        assert seen == list(range(64))

    def test_empty_and_single(self):
        assert plan_batches([], 8).batches == []
        p = plan_batches([Request(0, 5, 4)], 8)
        assert p.batches == [[0]] and p.waste == 0.0
