"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitserial, clustering, grad_compress, kv_compress, \
    quantizer
from repro.core.clustering import ClusterConfig
from repro.core.request_cluster import Request, plan_batches
from repro.models.attention import ring_slot_positions
from repro.optim import adamw

ints32 = st.integers(-(2**30), 2**30 - 1)


class TestQuantizerProperties:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(ints32, min_size=2, max_size=40))
    def test_unsigned_order_preserves_order(self, vals):
        """numeric order == lexicographic bit order after the sign flip —
        the invariant the whole bit-serial scan rests on."""
        q = jnp.asarray(vals, jnp.int32)
        u = np.asarray(quantizer.to_unsigned_order(q))
        order_q = np.argsort(np.asarray(q), kind="stable")
        order_u = np.argsort(u, kind="stable")
        np.testing.assert_array_equal(np.asarray(q)[order_q],
                                      np.asarray(q)[order_u])

    @settings(max_examples=40, deadline=None)
    @given(st.lists(ints32, min_size=1, max_size=20), st.sampled_from([16, 32]))
    def test_roundtrip(self, vals, bits):
        lim = 2 ** (bits - 1)
        vals = [max(-lim, min(lim - 1, v)) for v in vals]
        q = jnp.asarray(vals, jnp.int32)
        u = quantizer.to_unsigned_order(q, bits=bits)
        back = quantizer.from_unsigned_order(u, bits=bits)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


class TestMedianProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(ints32, min_size=1, max_size=31))
    def test_median_is_element_and_rank_correct(self, vals):
        x = np.asarray(vals, np.int32)
        u = quantizer.to_unsigned_order(jnp.asarray(x)[:, None])
        med = int(quantizer.from_unsigned_order(bitserial.median_bits(u))[0])
        assert med in x.tolist()
        n = len(x)
        below = int((x < med).sum())
        at_most = int((x <= med).sum())
        rank = (n + 1) // 2  # lower median, 1-based
        assert below < rank <= at_most

    @settings(max_examples=25, deadline=None)
    @given(st.lists(ints32, min_size=1, max_size=31),
           st.integers(-(2**10), 2**10))
    def test_translation_equivariance(self, vals, shift):
        x = np.asarray(vals, np.int64)
        xs = np.clip(x + shift, -(2**30), 2**30 - 1).astype(np.int32)
        x = (xs - shift).astype(np.int32)  # keep pair consistent
        m1 = int(quantizer.from_unsigned_order(bitserial.median_bits(
            quantizer.to_unsigned_order(jnp.asarray(x)[:, None])))[0])
        m2 = int(quantizer.from_unsigned_order(bitserial.median_bits(
            quantizer.to_unsigned_order(jnp.asarray(xs)[:, None])))[0])
        assert m2 - m1 == shift


class TestClusteringProperties:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_lloyd_inertia_never_increases(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(60, 3)).astype(np.float32))
        cfg = ClusterConfig(k=4, centroid="mean", metric="l2", max_iters=1,
                            seed=seed % 7)
        inertias = []
        cents = clustering.init_kmeanspp(jax.random.PRNGKey(seed % 5), x, 4)
        for _ in range(5):
            res = clustering.fit(x, cfg, cents, use_kernel=False)
            inertias.append(float(res.inertia))
            cents = res.centroids
        for a, b in zip(inertias, inertias[1:]):
            assert b <= a + 1e-3, inertias

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_assignment_is_nearest(self, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(40, 2)).astype(np.float32)
        c = rng.normal(size=(5, 2)).astype(np.float32)
        a, mind = clustering.assign_points(jnp.asarray(x), jnp.asarray(c),
                                           "l2", use_kernel=False)
        d = ((x[:, None, :] - c[None]) ** 2).sum(-1)
        np.testing.assert_array_equal(np.asarray(a), d.argmin(1))


class TestBatcherProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 2048), st.integers(1, 64)),
                    min_size=1, max_size=60),
           st.integers(1, 16))
    def test_every_request_scheduled_once(self, lens, bs):
        reqs = [Request(i, l, g) for i, (l, g) in enumerate(lens)]
        plan = plan_batches(reqs, batch_size=bs)
        seen = sorted(u for b in plan.batches for u in b)
        assert seen == list(range(len(reqs)))
        assert all(len(b) <= bs for b in plan.batches)
        assert 0.0 <= plan.waste < 1.0


class TestOptimizerProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.floats(0.1, 100.0), st.integers(1, 5))
    def test_clipped_norm_bounded(self, scale, dims):
        g = {"w": jnp.full((dims, 4), scale)}
        clipped, _ = adamw.clip_by_global_norm(g, 1.0)
        assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5


class TestRingBuffer:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 64), st.integers(0, 500))
    def test_ring_positions_cover_live_window(self, size, t):
        pos = np.asarray(ring_slot_positions(size, jnp.int32(t)))
        live = pos[(pos >= 0) & (pos < t)]
        expect = np.arange(max(0, t - size), t)
        np.testing.assert_array_equal(np.sort(live), expect)


class TestCoverageFrontier:
    """Invariants of the clustered-KV coverage frontier (``cov``) and the
    incremental re-compaction, under random lengths / centroid budgets /
    head counts.  Shapes come from a small sampled set so jit retraces
    stay bounded; lengths and refresh intervals are fully random.
    Sampled (S, C, R, H) = cache length, centroid budget, ring, heads."""

    @staticmethod
    def _mass_equals_cov(cc):
        h = np.asarray(cc["counts"]).shape[2]
        mass = np.asarray(cc["counts"]).sum(axis=(1, 2))
        np.testing.assert_allclose(mass, np.asarray(cc["cov"]) * h,
                                   rtol=1e-5, atol=1e-3)

    @staticmethod
    def _no_uncovered_eviction(cc, lengths, r, refresh):
        """Every position < t is represented exactly once (centroids below
        ``cov``, ring at [cov, t)), and positions the ring will evict
        within the next ``refresh`` decode steps are already covered."""
        cov = np.asarray(cc["cov"])
        t = np.asarray(lengths)
        assert (cov <= t).all(), (cov, t)
        assert (cov >= t - r).all(), "ring no longer holds an uncovered token"
        ring_pos = np.asarray(kv_compress.ring_positions(r, jnp.asarray(t)))
        live = (ring_pos >= cov[:, None]) & (ring_pos >= 0) \
            & (ring_pos < t[:, None])
        np.testing.assert_array_equal(live.sum(1), t - cov)  # exact partition
        evict_horizon = t + refresh - r  # deepest eviction before next pass
        assert ((cov >= evict_horizon) | (evict_horizon <= 0)).all(), \
            "a token would be evicted before a compaction covers it"

    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([(48, 4, 8, 1), (64, 6, 16, 2), (80, 8, 16, 4)]),
           st.sampled_from(["1", "half", "full"]),
           st.integers(0, 10_000))
    def test_compress_and_recompact_conserve_and_cover(self, shape, rmode,
                                                       seed):
        S, C, R, H = shape
        refresh = {"1": 1, "half": max(R // 2, 1), "full": R}[rmode]
        rng = np.random.default_rng(seed)
        cfg = kv_compress.KVCompressConfig(n_clusters=C, iters=2,
                                           keep_recent=R,
                                           refresh_every=refresh)
        B = 2
        lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
        k = jnp.asarray(rng.normal(size=(B, S, H, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, 8)), jnp.float32)
        cc = kv_compress.compress_cache_batched(k, v, jnp.asarray(lengths),
                                               cfg)
        r = min(R, S)
        self._mass_equals_cov(cc)
        self._no_uncovered_eviction(cc, lengths, r, cfg.refresh)

        # stream forward: advance each slot by <= refresh steps (the
        # engine's guarantee between compactions) and re-compact; the
        # frontier must stay monotone, conserve mass, and keep every
        # soon-to-be-evicted ring token covered
        for _ in range(3):
            adv = rng.integers(0, cfg.refresh + 1, size=B).astype(np.int32)
            lengths = lengths + adv
            prev_cov = np.asarray(cc["cov"])
            cc = kv_compress.recompact_clustered(cc, jnp.asarray(lengths),
                                                 cfg)
            assert (np.asarray(cc["cov"]) >= prev_cov).all()
            self._mass_equals_cov(cc)
            self._no_uncovered_eviction(cc, lengths, r, cfg.refresh)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000))
    def test_drained_slot_frontier_never_regresses(self, seed):
        """The engine passes length 0 for finished slots; their frontier
        (and mass) must hold steady instead of resetting."""
        rng = np.random.default_rng(seed)
        cfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                           keep_recent=8, refresh_every=4)
        k = jnp.asarray(rng.normal(size=(2, 48, 2, 8)), jnp.float32)
        lengths = jnp.asarray([40, 32], jnp.int32)
        cc = kv_compress.compress_cache_batched(k, k, lengths, cfg)
        cov0 = np.asarray(cc["cov"])
        cc2 = kv_compress.recompact_clustered(
            cc, jnp.asarray([44, 0], jnp.int32), cfg)
        cov2 = np.asarray(cc2["cov"])
        assert cov2[1] == cov0[1], "drained slot must keep its frontier"
        assert cov2[0] >= cov0[0]
        self._mass_equals_cov(cc2)


class TestAbsorbChunkProperties:
    """Invariants of streaming admission-time absorption
    (``kv_compress.absorb_chunk``): as a prompt's chunks stream into the
    tail ring, the coverage frontier must advance monotonically, total
    summary mass must equal the covered positions (nothing dropped,
    nothing double-counted), and the prompt-time centroid budget must
    confine all mass to its rows."""

    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([(8, 16, 2, 0), (6, 12, 1, 4), (4, 8, 2, 2)]),
           st.integers(1, 6),
           st.integers(0, 10_000))
    def test_streaming_absorb_conserves_and_advances(self, shape, chunk,
                                                     seed):
        C, R, H, budget = shape
        chunk = min(chunk, R)
        rng = np.random.default_rng(seed)
        cfg = kv_compress.KVCompressConfig(n_clusters=C, iters=2,
                                           keep_recent=R, refresh_every=2,
                                           prompt_clusters=budget)
        dh = 8
        cache = {
            "k_cents": jnp.zeros((1, C, H, dh), jnp.float32),
            "v_cents": jnp.zeros((1, C, H, dh), jnp.float32),
            "counts": jnp.zeros((1, C, H), jnp.float32),
            "k_tail": jnp.zeros((1, R, H, dh), jnp.float32),
            "v_tail": jnp.zeros((1, R, H, dh), jnp.float32),
            "cov": jnp.zeros((1,), jnp.int32),
        }
        plen = int(rng.integers(R + 1, 3 * R + 1))  # forces absorption
        fed = 0
        while fed < plen:
            cl = min(chunk, plen - fed)
            cov = int(np.asarray(cache["cov"])[0])
            if fed + cl - cov > R:
                # the engine's pre-feed absorb: make ring room for the
                # chunk, keeping the eviction-safety margin
                target = int(np.clip(fed + cl - R + cfg.refresh, 0, fed))
                prev_cov = cov
                cache = kv_compress.absorb_chunk(
                    cache, jnp.asarray([fed], jnp.int32),
                    jnp.asarray([target], jnp.int32), cfg)
                cov = int(np.asarray(cache["cov"])[0])
                assert cov == target >= prev_cov
                mass = np.asarray(cache["counts"]).sum()
                np.testing.assert_allclose(mass, cov * H, rtol=1e-5,
                                           atol=1e-3)
                # budgeted admission: all mass inside the first
                # ``prompt_budget`` centroid rows
                beyond = np.asarray(cache["counts"])[0, cfg.prompt_budget:]
                assert (beyond == 0).all()
            # stream the chunk into the ring at positions fed..fed+cl-1
            for i in range(cl):
                slot = (fed + i) % R
                row = rng.normal(size=(H, dh)).astype(np.float32)
                cache["k_tail"] = cache["k_tail"].at[0, slot].set(row)
                cache["v_tail"] = cache["v_tail"].at[0, slot].set(row)
            fed += cl
        # end-of-admission absorb: the engine's post-feed invariant
        target = int(np.clip(plen - R + cfg.refresh, 0, plen))
        if int(np.asarray(cache["cov"])[0]) < target:
            cache = kv_compress.absorb_chunk(
                cache, jnp.asarray([plen], jnp.int32),
                jnp.asarray([target], jnp.int32), cfg)
        cov = int(np.asarray(cache["cov"])[0])
        assert cov >= plen - R + cfg.refresh
        np.testing.assert_allclose(np.asarray(cache["counts"]).sum(),
                                   cov * H, rtol=1e-5, atol=1e-3)

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10_000))
    def test_noop_target_keeps_slot_bit_identical(self, seed):
        """target_cov <= cov must not perturb a slot at all — mid-decode
        neighbours of an admitting slot rely on this."""
        rng = np.random.default_rng(seed)
        cfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                           keep_recent=8, refresh_every=2)
        k = jnp.asarray(rng.normal(size=(2, 32, 2, 8)), jnp.float32)
        lengths = jnp.asarray([30, 24], jnp.int32)
        cc = kv_compress.compress_cache_batched(k, k, lengths, cfg)
        cov = np.asarray(cc["cov"])
        out = kv_compress.absorb_chunk(cc, lengths,
                                       jnp.asarray(cov, jnp.int32), cfg)
        for key in ("k_cents", "v_cents", "counts", "cov"):
            np.testing.assert_array_equal(np.asarray(out[key]),
                                          np.asarray(cc[key]), err_msg=key)


class TestBlockPoolProperties:
    """Allocator invariants of the paged KV memory manager
    (runtime/kv_pool.BlockPool) under random alloc/free/give-back
    sequences: no block is ever handed to two owners, the free list +
    live set always partition the pool exactly (alloc+free roundtrip
    restores it), and every block-table entry points at a live
    (ref > 0) block of the slot's own shard."""

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 2), st.sampled_from([4, 8]),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                              st.sampled_from(["alloc", "free_block",
                                               "free_slot", "covered"])),
                    min_size=1, max_size=60),
           st.integers(0, 10_000))
    def test_random_op_sequences_conserve_pool(self, shards, bsz, ops,
                                               seed):
        from repro.runtime import kv_pool
        rng = np.random.default_rng(seed)
        R = 16
        n_slots = 4 * shards
        pool = kv_pool.BlockPool(
            n_slots, R, kv_pool.PagedKVConfig(block_size=bsz),
            n_shards=shards, slots_per_shard=4)
        t_of = np.zeros(n_slots, np.int64)
        for slot_raw, bi_raw, op in ops:
            slot = (slot_raw * shards) % n_slots
            bi = bi_raw % pool.blocks_per_slot
            if op == "alloc":
                try:
                    gid = pool.alloc(slot, bi)
                except kv_pool.PoolExhausted:
                    pass
                else:
                    # the block came from the slot's own shard
                    assert (gid // pool.pool_blocks == pool.shard_of(slot))
            elif op == "free_block":
                pool.free_block(slot, bi)
            elif op == "free_slot":
                pool.free_slot(slot)
            else:
                # simulate decode progress then the compaction give-back
                t_of[slot] += int(rng.integers(1, R))
                cov = max(0, int(t_of[slot]) - int(rng.integers(0, R)))
                pool.free_covered(slot, int(t_of[slot]), cov)
            pool.check_invariants()
        # roundtrip: freeing everything restores the full free list
        for slot in range(n_slots):
            pool.free_slot(slot)
        pool.check_invariants()
        assert pool.allocated() == 0
        assert (pool.table == -1).all()
        assert pool.n_frees == pool.n_allocs

    def test_release_dead_block_raises_cleanly(self):
        """Releasing a block with ref == 0 must raise BEFORE any
        mutation: the count never underflows to -1 and the free list
        never sees a double insert (pinned: the retain path always
        guarded this, release did not)."""
        from repro.runtime import kv_pool
        pool = kv_pool.BlockPool(2, 16, kv_pool.PagedKVConfig(block_size=4))
        gid = pool.alloc(0, 0)
        pool.free_block(0, 0)
        free_before = sorted(pool._free[0])
        for _ in range(2):
            with pytest.raises(ValueError, match="dead block"):
                pool.release(gid)
            assert int(pool.ref[gid]) == 0          # no underflow
            assert sorted(pool._free[0]) == free_before  # no double insert
        with pytest.raises(ValueError, match="dead block"):
            pool.retain(gid)
        pool.check_invariants()

    def test_cow_never_mutates_a_referenced_block(self):
        """ensure() on a shared mapping must swap in a fresh block and
        hand back the copy pair — the shared block keeps its other
        references (and its payload, since the writer now owns a
        different block)."""
        from repro.runtime import kv_pool
        pool = kv_pool.BlockPool(2, 16, kv_pool.PagedKVConfig(block_size=4))
        gid = pool.alloc(0, 1)
        pool.adopt(1, 1, gid)                       # prefix-share mapping
        pool.retain(gid)                            # prefix-cache pin
        assert int(pool.ref[gid]) == 3
        pairs = pool.ensure(0, [1])                 # slot 0 writes → COW
        assert len(pairs) == 1 and pairs[0][0] == gid
        src, dst = pairs[0]
        assert int(pool.table[0, 1]) == dst != gid
        assert int(pool.table[1, 1]) == gid         # other owner untouched
        assert int(pool.ref[gid]) == 2 and int(pool.ref[dst]) == 1
        # exclusive owner: a second ensure is a no-op (no more copies)
        assert pool.ensure(0, [1]) == []
        assert pool.ensure(1, [1]) == [(gid, int(pool.table[1, 1]))]
        pool.check_invariants()

    def test_cow_pairs_survive_midlist_exhaustion(self):
        """A COW swap performed before ensure() raises PoolExhausted
        mid-list must keep its (src, dst) pair in the caller-owned
        accumulator — the retry sees an exclusively-owned block and
        re-emits nothing, so dropping the pair would silently skip the
        payload copy and leave the fresh block uninitialized."""
        from repro.runtime import kv_pool
        pool = kv_pool.BlockPool(
            1, 16, kv_pool.PagedKVConfig(block_size=4, pool_blocks=5))
        g0 = pool.alloc(0, 0)
        for bi in (1, 2, 3):
            pool.alloc(0, bi)
        pool.retain(g0)                 # shared: ensure must COW bi=0
        pool.free_block(0, 3)
        spare = pool._fresh(0)          # leave exactly one free block
        pairs: list = []
        with pytest.raises(kv_pool.PoolExhausted):
            # bi=0 COWs (consumes the last free block), bi=3 then raises
            pool.ensure(0, [0, 3], pairs)
        assert pairs == [(g0, int(pool.table[0, 0]))]
        pool.release(spare)
        pool.ensure(0, [0, 3], pairs)   # retry: no pair re-emitted
        assert len(pairs) == 1
        pool.check_invariants()

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 2), st.sampled_from([4, 8]),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                              st.sampled_from(["ensure", "adopt", "retain",
                                               "release", "free_slot",
                                               "covered"])),
                    min_size=1, max_size=60),
           st.integers(0, 10_000))
    def test_retain_release_cow_conserve_refs(self, shards, bsz, ops, seed):
        """Sharing invariants under random retain/adopt/COW/free
        sequences: every block's ref count equals its table mappings
        plus the external pins we hold, COW pairs always leave the
        source alive for its remaining owners, and releasing every pin +
        slot restores the full free list."""
        from repro.runtime import kv_pool
        rng = np.random.default_rng(seed)
        R = 16
        n_slots = 4 * shards
        pool = kv_pool.BlockPool(
            n_slots, R, kv_pool.PagedKVConfig(block_size=bsz),
            n_shards=shards, slots_per_shard=4)
        pins: list = []                 # external retains we must release
        t_of = np.zeros(n_slots, np.int64)
        for slot_raw, bi_raw, op in ops:
            slot = (slot_raw * shards) % n_slots
            bi = bi_raw % pool.blocks_per_slot
            if op == "ensure":
                try:
                    pairs = pool.ensure(slot, [bi])
                except kv_pool.PoolExhausted:
                    pairs = []
                for src, dst in pairs:
                    assert int(pool.ref[src]) >= 1, \
                        "COW dropped a block others still reference"
                    assert int(pool.ref[dst]) == 1
                    assert int(pool.table[slot, bi]) == dst
            elif op == "adopt":
                # share a live same-shard mapping into this slot
                base = pool.shard_of(slot) * pool.slots_per_shard
                donor = base + int(rng.integers(0, pool.slots_per_shard))
                gid = int(pool.table[donor, bi])
                if gid >= 0 and pool.table[slot, bi] < 0:
                    pool.adopt(slot, bi, gid)
            elif op == "retain":
                gid = int(pool.table[slot, bi])
                if gid >= 0:
                    pool.retain(gid)
                    pins.append(gid)
            elif op == "release":
                if pins:
                    pool.release(pins.pop(rng.integers(0, len(pins))))
            elif op == "free_slot":
                pool.free_slot(slot)
            else:
                t_of[slot] += int(rng.integers(1, R))
                cov = max(0, int(t_of[slot]) - int(rng.integers(0, R)))
                pool.free_covered(slot, int(t_of[slot]), cov)
            pool.check_invariants()
            # ref conservation: mappings + our pins, exactly
            mapped = pool.table[pool.table >= 0]
            for gid in np.unique(mapped):
                expect = int((mapped == gid).sum()) + pins.count(int(gid))
                assert int(pool.ref[gid]) == expect, (gid, expect)
        for gid in pins:
            pool.release(gid)
        for slot in range(n_slots):
            pool.free_slot(slot)
        pool.check_invariants()
        assert pool.allocated() == 0
        assert (pool.table == -1).all()
        assert pool.n_frees == pool.n_allocs

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 500), st.integers(0, 500), st.sampled_from([2, 4]))
    def test_write_and_live_blocks_agree_with_ring_claims(self, t, back,
                                                          bsz):
        """write_blocks(start, count) covers exactly the blocks whose
        offsets a position write touches; live_blocks ⊆ mapped blocks a
        real stream would hold, and a block never appears in both the
        'dead after free_covered' set and live_blocks."""
        from repro.runtime import kv_pool
        R = 16
        cov = max(0, t - back)
        live = kv_pool.live_blocks(t, cov, R, bsz)
        claims = kv_pool.ring_claims(t, R)
        for b in range(R // bsz):
            blk = claims[b * bsz:(b + 1) * bsz]
            has_live = bool(((blk >= cov) & (blk < t)).any())
            assert (b in live) == has_live
        wb = kv_pool.write_blocks(t, 3, R, bsz)
        for i in range(3):
            assert ((t + i) % R) // bsz in wb


class TestTemplateStoreProperties:
    """Conservation invariants of the persistent template store
    (runtime/template_store.TemplateStore) under interleaved
    register/lookup/evict/invalidate/clear traffic spanning simulated
    serve boundaries: every block's ref count equals its table mappings
    plus the store's pins, the inter-serve drain leaves exactly
    ``pinned_blocks()`` allocated, eviction never touches an entry with
    an adoption in flight, and clear/invalidate/epoch-flip always drain
    the pins to zero."""

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 2),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2),
                              st.sampled_from(["register", "adopt", "evict",
                                               "invalidate", "clear",
                                               "serve_boundary"])),
                    min_size=1, max_size=50),
           st.integers(0, 10_000))
    def test_store_pins_conserve_refs_across_serves(self, shards, ops,
                                                    seed):
        from repro.runtime import kv_pool
        from repro.runtime.template_store import (TemplateStore,
                                                  TemplateStoreConfig)
        rng = np.random.default_rng(seed)
        R, bsz, chunk = 16, 4, 8
        n_slots = 2 * shards
        pool = kv_pool.BlockPool(
            n_slots, R, kv_pool.PagedKVConfig(block_size=bsz,
                                              pool_blocks=32),
            n_shards=shards, slots_per_shard=2)
        store = TemplateStore(TemplateStoreConfig(max_entries=3,
                                                  promote_after=2))
        epoch = ("cfg", "ccfg", chunk)
        assert store.bind(epoch, shards, pool)       # first bind: cold

        def prompt_of(fam):                          # distinct families
            return np.arange(24, dtype=np.int32) + 100 * fam

        def held_pins():
            out = []
            for m in store._maps:
                for e in m.values():
                    out.extend(int(g) for g in e.blocks.values())
            return out

        def check():
            pool.check_invariants()
            held = held_pins()
            mapped = pool.table[pool.table >= 0]
            live = set(int(g) for g in np.unique(mapped)) | set(held)
            for gid in live:
                expect = int((mapped == gid).sum()) + held.count(gid)
                assert int(pool.ref[gid]) == expect, (gid, expect)
            assert pool.allocated() == len(live)

        for slot_raw, fam, op in ops:
            slot = slot_raw % n_slots
            shard = pool.shard_of(slot)
            p = prompt_of(fam)
            if op == "register":
                fed = int(rng.choice([chunk, 2 * chunk]))
                bis = kv_pool.write_blocks(0, fed, R, bsz)
                for bi in bis:
                    pool.alloc(slot, bi)
                store.register(shard, p, fed, 0,
                               {bi: int(pool.table[slot, bi])
                                for bi in bis}, snap=object(),
                               cluster=store.assign(
                                   p, store.prefix_digests(p, chunk)))
            elif op == "adopt":
                d = store.prefix_digests(p, chunk)
                e = store.lookup(shard, p, chunk, digests=d)
                if e is not None:
                    # a pool-pressure reclaim landing between lookup and
                    # restore must never drop the in-flight entry
                    store.evict_lru(shard)
                    assert any(v is e
                               for v in store._maps[shard].values())
                    store.adoption_done(e)
            elif op == "evict":
                store.evict_lru(shard)
            elif op == "invalidate":
                store.invalidate()
                assert store.pinned_blocks() == 0
            elif op == "clear":
                store.clear()
                assert store.pinned_blocks() == 0
            else:                       # serve_boundary: drain + rebind
                for s in range(n_slots):
                    pool.free_slot(s)
                assert pool.allocated() == store.pinned_blocks()
                assert not store.bind(epoch, shards, pool)  # warm: kept
                assert pool.allocated() == store.pinned_blocks()
            check()
        # final serve drain + epoch flip: the pool must come all the
        # way back (a new config can never see a stale snapshot)
        for s in range(n_slots):
            pool.free_slot(s)
        assert pool.allocated() == store.pinned_blocks()
        assert store.bind(("other-config",), shards, pool)  # cold
        assert store.pinned_blocks() == 0
        assert pool.allocated() == 0
        assert pool.n_frees == pool.n_allocs
        pool.check_invariants()


class TestRetentionPolicyProperties:
    """Invariants of the retention-policy layer (core/retention.py):
    sweeps driven by a policy may only free storage the policy marks
    dead, WindowRetention never retires an in-window or unwritten
    position, QuotaRetention conserves the pool (nothing freed before
    slot exit, everything freed after), and FrontierRetention reproduces
    the legacy ``free_covered`` sweep exactly."""

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from([8, 16]), st.sampled_from([2, 4]),
           st.lists(st.integers(1, 12), min_size=1, max_size=12),
           st.integers(0, 10_000))
    def test_window_sweep_never_frees_live_positions(self, window, bsz,
                                                     steps, seed):
        """Stream a slot forward through random advances, backing every
        ring write with pool blocks and sweeping under WindowRetention
        after each: any claim in [t - window, t) must keep its block
        mapped, and the advance() deltas must sum to the retired total."""
        from repro.core import retention
        from repro.runtime import kv_pool
        R = window                      # ring sized to the window ('L')
        pool = kv_pool.BlockPool(1, R, kv_pool.PagedKVConfig(block_size=bsz),
                                 full_tail_resident=False)
        wr = retention.WindowRetention(window, 1)
        t = 0
        retired = 0
        for adv in steps:
            for b in kv_pool.write_blocks(t, adv, R, bsz):
                pool.alloc(0, b)
            t += adv
            retired += wr.advance(0, t)
            pool.free_retired(0, t, wr)
            pool.check_invariants()
            claims = kv_pool.ring_claims(t, R)
            for bi in range(R // bsz):
                blk = claims[bi * bsz:(bi + 1) * bsz]
                in_window = ((blk >= max(0, t - window)) & (blk < t)).any()
                if in_window:
                    assert pool.table[0, bi] >= 0, \
                        "sweep freed a block holding an in-window position"
        assert retired == max(0, t - window)
        assert wr.retire_lo(0, t) == max(0, t - window)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 200), st.integers(0, 64), st.sampled_from([2, 4]),
           st.integers(1, 40))
    def test_quota_conserves_pool_until_slot_exit(self, plen, max_new, bsz,
                                                  steps):
        """admit_blocks covers the request's full written depth (clamped
        to the slot budget), mid-stream sweeps under QuotaRetention free
        NOTHING (keep_unwritten reservations), and slot exit returns
        every block: frees == allocs."""
        from repro.core import retention
        from repro.runtime import kv_pool
        R = 64
        pool = kv_pool.BlockPool(1, R, kv_pool.PagedKVConfig(block_size=bsz))
        quota = retention.QuotaRetention(bsz, pool.blocks_per_slot)
        need = quota.admit_blocks(plen, max_new)
        depth = plen + max(1, max_new) - 1
        assert 1 <= need <= pool.blocks_per_slot
        assert need * bsz >= min(depth, R)       # budget covers the claim
        assert (need - 1) * bsz < max(depth, 1)  # and is not padded
        for b in range(need):
            pool.alloc(0, b)
        before = pool.allocated()
        for t in range(0, min(depth, R), max(1, min(depth, R) // steps)):
            assert pool.free_retired(0, t, quota) == 0
            assert quota.retire_lo(0, t) == 0
        assert pool.allocated() == before        # nothing retired mid-flight
        pool.free_slot(0)
        pool.check_invariants()
        assert pool.allocated() == 0
        assert pool.n_frees == pool.n_allocs

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 2), st.sampled_from([4, 8]),
           st.lists(st.tuples(st.integers(0, 3), st.integers(1, 15),
                              st.integers(0, 16), st.booleans()),
                    min_size=1, max_size=40),
           st.integers(0, 10_000))
    def test_frontier_policy_matches_legacy_free_covered(self, shards, bsz,
                                                         ops, seed):
        """FrontierRetention sweeps must free the exact block sets the
        pre-policy ``free_covered(cov, exclude=)`` freed — run the same
        random stream through two pools, one per API, and compare freed
        counts and block tables after every op."""
        from repro.core import retention
        from repro.runtime import kv_pool
        rng = np.random.default_rng(seed)
        R = 16
        n_slots = 4 * shards
        mk = lambda: kv_pool.BlockPool(  # noqa: E731
            n_slots, R, kv_pool.PagedKVConfig(block_size=bsz),
            n_shards=shards, slots_per_shard=4)
        pool_a, pool_b = mk(), mk()
        ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=1,
                                            keep_recent=R, refresh_every=4)
        fr = retention.FrontierRetention(n_slots, ccfg)
        t_of = np.zeros(n_slots, np.int64)
        for slot_raw, adv, back, protect in ops:
            slot = (slot_raw * shards) % n_slots
            for b in kv_pool.write_blocks(int(t_of[slot]), adv, R, bsz):
                pool_a.alloc(slot, b)
                pool_b.alloc(slot, b)
            t_of[slot] += adv
            t = int(t_of[slot])
            cov = max(0, t - back)
            excl = (kv_pool.write_blocks(t, 1, R, bsz) if protect else [])
            fr.set_frontier(slot, cov)
            fr.protect_write(slot, excl)
            freed_a = pool_a.free_retired(slot, t, fr)
            fr.clear_protection(slot)
            freed_b = pool_b.free_covered(slot, t, cov, exclude=excl)
            assert freed_a == freed_b
            np.testing.assert_array_equal(pool_a.table, pool_b.table)
            pool_a.check_invariants()


class TestGradCompressProperties:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000))
    def test_fixed_codebook_assignment_idempotent(self, seed):
        """With a FIXED codebook, dequantize→requantize is exact (nearest-
        level assignment is idempotent).  (Refitting the codebook is NOT
        idempotent — hypothesis found Lloyd merging near levels, which is
        why error feedback exists.)"""
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
        cfg = grad_compress.CompressConfig(k=8, iters=6)
        idx1, cents = grad_compress.quantize_tensor(g, cfg)
        g1 = grad_compress.dequantize_tensor(idx1, cents)
        d = jnp.abs(g1.reshape(-1)[:, None] - cents[None, :])
        idx2 = jnp.argmin(d, axis=1).astype(jnp.uint8).reshape(g.shape)
        g2 = grad_compress.dequantize_tensor(idx2, cents)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


class TestSchedulerSwapProperties:
    """Preempt / swap / resume / shed state machine over the real
    BlockPool + SLOScheduler (no device arrays): whatever order the
    brownout ladder fires in, the pool must conserve blocks — every
    allocated block is mapped by exactly one active slot, parked
    requests hold zero device blocks (their payload is host-side), a
    resume re-adopts a block only if its (gid, generation) provably
    survived, and the protected class is never shed."""

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 2), st.sampled_from([4, 8]),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 4),
                              st.sampled_from(["admit", "decode", "preempt",
                                               "resume", "shed_active",
                                               "shed_parked"])),
                    min_size=1, max_size=60),
           st.integers(0, 10_000))
    def test_preempt_swap_resume_shed_conserve_pool(self, shards, bsz,
                                                    ops, seed):
        from repro.runtime import kv_pool
        from repro.runtime.scheduler import SLOConfig, SLOScheduler

        rng = np.random.default_rng(seed)
        R = 16
        n_slots = 3 * shards
        pool = kv_pool.BlockPool(
            n_slots, R, kv_pool.PagedKVConfig(block_size=bsz),
            n_shards=shards, slots_per_shard=3)
        slo = SLOScheduler(SLOConfig(), n_slots)
        occupant = {}          # slot -> (uid, priority)
        t_of = np.zeros(n_slots, np.int64)
        next_uid = 0
        n_shed_parked = 0

        def mapped(slot):
            return int((pool.table[slot] >= 0).sum())

        def conserve():
            pool.check_invariants()
            # active slots own every allocated block; parked own none
            assert pool.allocated() == sum(mapped(s) for s in occupant)
            for s in range(n_slots):
                if s not in occupant:
                    assert mapped(s) == 0, s

        for slot_raw, arg, op in ops:
            slot = (slot_raw * shards) % n_slots
            if op == "admit" and slot not in occupant:
                prio = int(arg % 2)
                try:
                    for b in kv_pool.write_blocks(0, 1 + arg, R, bsz):
                        pool.alloc(slot, b)
                except kv_pool.PoolExhausted:
                    pool.free_slot(slot)
                else:
                    occupant[slot] = (next_uid, prio)
                    t_of[slot] = 1 + arg
                    next_uid += 1
            elif op == "decode" and slot in occupant:
                try:
                    for b in kv_pool.write_blocks(int(t_of[slot]), 1, R,
                                                  bsz):
                        pool.alloc(slot, b)
                except kv_pool.PoolExhausted:
                    pass
                else:
                    t_of[slot] += 1
            elif op == "preempt" and occupant:
                # the engine preempts via pick_victim over the actives
                cands = [(p, mapped(s), s)
                         for s, (_, p) in occupant.items()]
                v = slo.pick_victim(cands,
                                    max(c[0] for c in cands) + 1)
                uid, prio = occupant.pop(v)
                held = pool.release_slot(v)
                from repro.runtime.scheduler import SwapRecord
                rec = SwapRecord(uid=uid, priority=prio,
                                 pos=int(t_of[v]), cur=0, fed=0,
                                 since_tok=0, cov=0, max_new_tokens=4,
                                 deadline_ms=0.0, held=held, snap=None,
                                 tails=None, epoch=0, seq=0,
                                 n_blocks_swapped=len(held))
                slo.record_swap(rec)
            elif op == "resume" and slo.backlog_size() > 0:
                free = [s for s in range(n_slots) if s not in occupant]
                rec = slo.peek_resume()
                if free and rec is not None:
                    slot = free[arg % len(free)]
                    ok = True
                    for bi, (gid, gen) in rec.held.items():
                        if not pool.readopt(slot, bi, gid, gen):
                            try:
                                pool.alloc(slot, bi)
                            except kv_pool.PoolExhausted:
                                ok = False
                                break
                    if not ok:
                        pool.free_slot(slot)   # defer: nothing half-done
                    else:
                        # release_slot bumps gen when ref hits 0, so a
                        # re-adoption here can only be a block a co-owner
                        # kept live; either way every held index is now
                        # mapped on the new slot
                        assert all(pool.table[slot, bi] >= 0
                                   for bi in rec.held)
                        occupant[slot] = (rec.uid, rec.priority)
                        t_of[slot] = rec.pos
                        slo.pop_record(rec)
            elif op == "shed_active" and occupant:
                lows = [(p, s) for s, (_, p) in occupant.items()
                        if not slo.is_high(p)]
                if lows:
                    _, v = min(lows)
                    uid, prio = occupant.pop(v)
                    slo.shed_uid(uid, prio)
                    pool.free_slot(v)
            elif op == "shed_parked":
                rec = slo.pick_shed()
                if rec is not None:
                    slo.shed_record(rec)
                    n_shed_parked += 1
            conserve()

        # protected class never shed, ladder accounting conserved:
        # every swap-out either swapped back in, is still parked, or
        # was shed from the backlog — no request vanishes
        assert slo.shed_high == 0
        assert slo.shed_uids <= set(range(next_uid))
        parked = {r.uid for r in slo._backlog}
        assert slo.shed_uids.isdisjoint(
            {u for u, _ in occupant.values()} | parked)
        assert slo.swaps_out == (slo.swaps_in + slo.backlog_size()
                                 + n_shed_parked)
        # drain: every remaining mapping freed -> pool fully restored
        for s in list(occupant):
            pool.free_slot(s)
        pool.check_invariants()
        assert pool.allocated() == 0
        assert (pool.table == -1).all()

    def test_readopt_rejects_recycled_block(self):
        """A released block that was re-allocated (generation bumped)
        must NOT re-adopt — the device bytes no longer match the host
        copy, so the resume has to re-upload instead."""
        from repro.runtime import kv_pool
        pool = kv_pool.BlockPool(2, 16, kv_pool.PagedKVConfig(block_size=4))
        pool.alloc(0, 0)
        held = pool.release_slot(0)
        (gid, gen), = held.values()
        # the release itself bumped the generation (ref hit 0): even an
        # UN-recycled free-list block refuses — a fresh alloc may
        # overwrite it at any time, so identity is unprovable
        assert not pool.readopt(0, 0, gid, gen)
        assert pool.table[0, 0] == -1               # nothing half-adopted
        # the fast path that DOES re-adopt: the block stayed live the
        # whole time because a second owner (prefix sharing / pin) held
        # it — ref never hit zero, generation never moved
        g2 = pool.alloc(0, 0)
        pool.retain(g2)                             # simulated co-owner
        held2 = pool.release_slot(0)
        (gid2, gen2), = held2.values()
        assert pool.ref[gid2] == 1                  # co-owner keeps it live
        assert pool.readopt(0, 0, gid2, gen2)
        assert pool.table[0, 0] == gid2
        assert pool.ref[gid2] == 2
        pool.free_block(0, 0)                       # drop the mapping
        pool.release(gid2)                          # co-owner lets go
        pool.check_invariants()
        assert pool.allocated() == 0


class TestTraceSchemaProperties:
    """The trace validator (runtime/telemetry.py) accepts every
    well-formed request lifecycle the engine can emit — any number of
    swap/resume round-trips per uid, any terminal shape (finish, shed
    from a slot, shed while parked) — and flags the canonical
    corruptions: a missing or duplicated terminal, a resume with no
    matching swap_out, and totals that don't reconcile."""

    END = ("finish", "shed", "park_shed")

    @staticmethod
    def _build(plan):
        """Synthesize a lifecycle event list from ``plan``: one entry
        per uid of (tokens-per-segment list, terminal shape), with a
        synthetic monotone clock and one slot track per uid — the same
        span geometry the engine emits (resume/swap_out spans nested in
        the run segment they border, equal-end allowed)."""
        events, clock = [], [0.0]
        totals = {"sched_swaps_out": 0.0, "sched_swaps_in": 0.0,
                  "sched_sheds": 0.0, "gen_tokens": 0.0}

        def tick():
            clock[0] += 1.0
            return clock[0]

        def ev(name, ts, uid, tid, **args):
            events.append({"name": name, "ph": "i", "ts": ts, "pid": 0,
                           "tid": tid, "uid": uid, "args": args})

        def sp(name, ts, dur, uid, tid, **args):
            events.append({"name": name, "ph": "X", "ts": ts, "dur": dur,
                           "pid": 0, "tid": tid, "uid": uid, "args": args})

        for uid, (segs, end) in enumerate(plan):
            tid = f"slot{uid}"
            ev("queued", tick(), uid, "queue")
            for si, toks in enumerate(segs):
                last = si == len(segs) - 1
                t0 = tick()
                if si > 0:                      # resuming a parked uid
                    sp("resume", t0, 0.25, uid, tid)
                    totals["sched_swaps_in"] += 1
                t1 = tick()
                totals["gen_tokens"] += toks
                if not last or end == "park_shed":
                    sp("swap_out", t1, 0.25, uid, tid)
                    sp("run", t0, t1 + 0.25 - t0, uid, tid, tokens=toks)
                    totals["sched_swaps_out"] += 1
                else:
                    sp("run", t0, t1 - t0, uid, tid, tokens=toks)
                    if end == "shed":
                        ev("shed", t1, uid, tid)
                        totals["sched_sheds"] += 1
                    else:
                        ev("finish", t1, uid, tid)
            if end == "park_shed":              # brownout while parked
                ev("shed", tick(), uid, "engine")
                totals["sched_sheds"] += 1
        return events, totals

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.lists(st.integers(0, 5), min_size=1,
                                       max_size=3),
                              st.sampled_from(END)),
                    min_size=1, max_size=5))
    def test_well_formed_lifecycles_validate_clean(self, plan):
        from repro.runtime.telemetry import validate_trace
        events, totals = self._build(plan)
        assert validate_trace(events, totals=totals) == []

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.lists(st.integers(0, 5), min_size=1,
                                       max_size=3),
                              st.sampled_from(END)),
                    min_size=1, max_size=4))
    def test_corruptions_are_flagged(self, plan):
        from repro.runtime.telemetry import validate_trace
        events, totals = self._build(plan)
        # dropping uid 0's terminal orphans its run span
        cut = [e for e in events
               if not (e["uid"] == 0 and e["name"] in ("finish", "shed"))]
        assert any("uid 0" in p and "terminal" in p
                   for p in validate_trace(cut))
        # duplicating a terminal double-finishes the request
        dup = events + [{"name": "finish", "ph": "i", "ts": 1e9,
                         "pid": 0, "tid": "slot0", "uid": 0, "args": {}}]
        assert validate_trace(dup) != []
        # a resume with no park is a pairing violation
        orphan = events + [{"name": "resume", "ph": "X", "ts": 2e9,
                            "dur": 1.0, "pid": 0, "tid": "slot0",
                            "uid": 999, "args": {}}]
        assert any("resume without matching swap_out" in p
                   for p in validate_trace(orphan))
        # token totals that don't add up fail reconciliation
        off = dict(totals, gen_tokens=totals["gen_tokens"] + 1)
        assert any("gen_tokens" in p
                   for p in validate_trace(events, totals=off))


class TestRecurrentStateProperties:
    """Layer-state-family invariants for the recurrent side
    (core/layer_state.py): a ``clustered_slot_state`` checkpoint of a
    recurrent slot, restored at any decode boundary — even into a fresh
    cache or a different slot index — replays the remaining tokens
    bit-identically to the uninterrupted run, and the SLO swap-bytes
    ledger conserves mixed-family payloads (ring blocks + recurrent
    state bytes) through any preempt/resume/shed interleaving."""

    _CFGS = {}

    @classmethod
    def _cfg(cls, kind):
        if kind not in cls._CFGS:
            from repro.models.config import ModelConfig, SSMConfig
            from repro.models import transformer as tfm
            if kind == "M":
                cfg = ModelConfig(
                    name="pm", family="ssm", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab=64, pad_vocab_multiple=16, dtype="float32",
                    layer_pattern="M",
                    ssm=SSMConfig(d_state=8, d_conv=4, expand=2,
                                  head_dim=16, n_groups=1, chunk=16))
            else:
                cfg = ModelConfig(
                    name="pr", family="hybrid", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab=64, pad_vocab_multiple=16, dtype="float32",
                    layer_pattern="R", lru_width=32)
            params = tfm.init_params(jax.random.PRNGKey(0), cfg)
            cls._CFGS[kind] = (cfg, params)
        return cls._CFGS[kind]

    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from(["M", "R"]), st.integers(1, 10),
           st.integers(1, 6), st.integers(0, 1), st.integers(0, 1),
           st.integers(0, 10_000))
    def test_checkpoint_restore_replays_bit_identical(
            self, kind, boundary, extra, slot, dest_slot, seed):
        """Decode T = boundary + extra steps uninterrupted; checkpoint
        ``slot`` at the boundary, restore into ``dest_slot`` of a FRESH
        cache, replay the tail — every replayed logits row must be
        bitwise equal.  This is the property the engine's preempt→swap→
        resume and template-store prefix sharing paths rest on: for the
        recurrent family the state IS the checkpoint."""
        from repro.models import transformer as tfm
        cfg, params = self._cfg(kind)
        T = boundary + extra
        rng = np.random.default_rng(seed)
        toks = rng.integers(0, 64, size=(2, T)).astype(np.int32)

        cache = tfm.init_cache(cfg, 2, max_seq=32)
        logits_ref = []
        snap = None
        for t in range(T):
            if t == boundary:
                snap = tfm.clustered_slot_state(cache, slot)
            lg, cache = tfm.decode_step(
                params, cfg, cache, jnp.asarray(toks[:, t:t + 1]),
                jnp.int32(t))
            logits_ref.append(np.asarray(lg[slot]))

        fresh = tfm.init_cache(cfg, 2, max_seq=32)
        fresh = tfm.restore_clustered_slot_state(fresh, snap, dest_slot)
        for i, t in enumerate(range(boundary, T)):
            row = np.zeros((2, 1), np.int32)
            row[dest_slot, 0] = toks[slot, t]
            lg, fresh = tfm.decode_step(params, cfg, fresh,
                                        jnp.asarray(row), jnp.int32(t))
            np.testing.assert_array_equal(
                np.asarray(lg[dest_slot]), logits_ref[boundary + i],
                err_msg=f"replay step {t} diverged after restore")

    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from([4, 8]), st.integers(1, 64),
           st.lists(st.tuples(st.integers(0, 6), st.integers(0, 4096),
                              st.sampled_from(["preempt", "resume",
                                               "shed_parked"])),
                    min_size=1, max_size=50),
           st.integers(0, 10_000))
    def test_swap_bytes_ledger_conserves_mixed_families(self, bsz, bpt,
                                                        ops, seed):
        """The engine credits ``len(held) * block_size * tail_bpt +
        state_bytes`` at preempt and debits the same expression from the
        parked SwapRecord at resume/shed.  Whatever the interleaving —
        ring-only records (state_bytes 0) mixed with recurrent-family
        records — the ledger equals the sum over the parked backlog at
        every step, never goes negative, and drains to exactly zero."""
        from repro.runtime.scheduler import SLOConfig, SLOScheduler, \
            SwapRecord
        slo = SLOScheduler(SLOConfig(max_swapped=64), 8)
        next_uid = 0

        def price(rec):
            return rec.n_blocks_swapped * bsz * bpt + rec.state_bytes

        for nb, state_b, op in ops:
            if op == "preempt":
                held = {bi: (bi, 0) for bi in range(nb)}
                rec = SwapRecord(uid=next_uid, priority=0, pos=1, cur=0,
                                 fed=0, since_tok=0, cov=0,
                                 max_new_tokens=4, deadline_ms=0.0,
                                 held=held, snap=None, tails=None,
                                 epoch=0, seq=next_uid,
                                 n_blocks_swapped=nb, state_bytes=state_b)
                next_uid += 1
                slo.record_swap(rec)
                slo.swap_bytes += price(rec)
            elif op == "resume":
                rec = slo.peek_resume()
                if rec is not None:
                    slo.pop_record(rec)
                    slo.swap_bytes -= price(rec)
            elif op == "shed_parked":
                rec = slo.pick_shed()
                if rec is not None:
                    slo.shed_record(rec)
                    slo.swap_bytes -= price(rec)
            assert slo.swap_bytes >= 0
            assert slo.swap_bytes == sum(price(r) for r in slo._backlog)

        while slo.backlog_size() > 0:          # drain: resume everything
            rec = slo.peek_resume()
            slo.pop_record(rec)
            slo.swap_bytes -= price(rec)
        assert slo.swap_bytes == 0
