"""Serving telemetry layer (runtime/telemetry.py + engine integration).

Unit level: the typed metrics registry (get-or-create, kind collision,
begin_serve per-serve drop vs lifetime persist, exact-then-bucketed
histogram quantiles, markdown reference table), the trace-schema
validator on synthetic good/bad event sequences, and the Chrome
trace-event exporter roundtrip.  Engine level: lifecycle tracing must be
schedule-invisible (greedy tokens bit-identical with tracing on vs off,
including under preemption/swap/resume pressure), the emitted trace must
satisfy every schema invariant and reconcile against ``last_stats``, and
dynamic per-serve keys from one serve must never leak into the next
serve's stats (the stale-``last_stats``-keys regression).
"""

import json

import numpy as np
import pytest

import jax

from repro.core import kv_compress
from repro.core.request_cluster import Request
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.kv_pool import PagedKVConfig
from repro.runtime.scheduler import SLOConfig
from repro.runtime.server import Server, ServerConfig
from repro.runtime.telemetry import (TRACE_SCHEMA, MetricsRegistry,
                                     TelemetryConfig, Tracer,
                                     events_from_chrome, phase_breakdown,
                                     validate_chrome_file,
                                     validate_jsonl_file, validate_trace,
                                     write_chrome_trace, write_jsonl)
from repro.runtime.template_store import TemplateStoreConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")
CCFG = kv_compress.KVCompressConfig(n_clusters=8, iters=4, keep_recent=16,
                                    refresh_every=8)


@pytest.fixture(scope="module")
def params():
    return tfm.init_params(jax.random.PRNGKey(0), TINY)


def _mixed_stream(n=8, n_high=3, seed=3, vocab=64):
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        plen = int(rng.integers(6, 30))
        prompts[i] = rng.integers(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(i, plen, int(rng.integers(6, 14)),
                            priority=1 if i >= n - n_high else 0))
    return reqs, prompts


# ---------------------------------------------------------------------------
# unit: metrics registry
# ---------------------------------------------------------------------------


class TestMetricsRegistry:

    def test_get_or_create_and_kind_collision(self):
        reg = MetricsRegistry()
        c = reg.counter("x", "help")
        c.add(2)
        assert reg.counter("x") is c            # same object back
        assert reg.flat_view() == {"x": 2.0}
        with pytest.raises(ValueError):
            reg.gauge("x")                      # kind collision

    def test_begin_serve_drops_per_serve_keeps_persist(self):
        reg = MetricsRegistry()
        reg.gauge("template_cluster0_cohesion").set(0.9)
        reg.counter("sched_preemptions").add(3)
        reg.counter("template_hits_total", persist=True).set_to(7)
        reg.begin_serve()
        assert reg.flat_view() == {"template_hits_total": 7.0}
        # republish is monotone: a fresh store view can't move it back
        reg.counter("template_hits_total", persist=True).set_to(5)
        assert reg.flat_view() == {"template_hits_total": 7.0}

    def test_histogram_exact_matches_percentile(self):
        reg = MetricsRegistry()
        h = reg.histogram("ttft", quantiles=(50, 95, 99), scale=1e3,
                          suffix="_ms")
        rng = np.random.default_rng(0)
        vals = rng.exponential(0.05, size=200)
        for v in vals:
            h.observe(v)
        assert h.exact
        view = h.view()
        for q in (50, 95, 99):
            want = float(np.percentile(vals, q) * 1e3)
            assert view[f"ttft_p{q}_ms"] == want   # bit-identical

    def test_histogram_bucket_fallback_past_cap(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", quantiles=(50,), max_samples=8)
        for v in np.linspace(0.5, 4.0, 32):
            h.observe(v)
        assert not h.exact
        got = h.quantile(50)
        # bucketed estimate stays inside the observed range
        assert 0.5 <= got <= 8.0
        assert h.count == 32

    def test_flat_view_insertion_order(self):
        reg = MetricsRegistry()
        for name in ("b", "a", "c"):
            reg.gauge(name).set(1.0)
        assert list(reg.flat_view()) == ["b", "a", "c"]

    def test_reference_table(self):
        reg = MetricsRegistry()
        reg.counter("gen_tokens", "tokens generated")
        reg.counter("template_hits_total", "lifetime hits", persist=True)
        reg.histogram("ttft", "time to first token", quantiles=(50, 95),
                      suffix="_ms")
        table = reg.reference_table()
        assert "| `gen_tokens` | counter | tokens generated |" in table
        assert "counter (lifetime)" in table
        assert "`ttft_p50_ms`, `ttft_p95_ms`" in table


# ---------------------------------------------------------------------------
# unit: trace validator on synthetic sequences
# ---------------------------------------------------------------------------


def _ev(name, ts, uid=None, tid="engine", pid=0, **args):
    return {"name": name, "ph": "i", "ts": float(ts), "pid": pid,
            "tid": tid, "uid": uid, "args": args}


def _sp(name, ts, dur, uid=None, tid="engine", pid=0, **args):
    return {"name": name, "ph": "X", "ts": float(ts), "dur": float(dur),
            "pid": pid, "tid": tid, "uid": uid, "args": args}


class TestValidateTrace:

    def _good(self):
        return [
            _ev("queued", 0.0, uid=1, tid="queue"),
            _ev("queued", 1.0, uid=2, tid="queue"),
            _sp("engine_step", 10.0, 5.0, kind="mixed"),
            _ev("first_token", 15.0, uid=1, tid="slot0"),
            _sp("swap_out", 20.0, 2.0, uid=1, tid="slot0"),
            _sp("run", 5.0, 22.0, uid=1, tid="slot0", tokens=3),
            _sp("resume", 30.0, 2.0, uid=1, tid="slot0"),
            _ev("finish", 40.0, uid=1, tid="slot0"),
            _sp("run", 30.0, 10.0, uid=1, tid="slot0", tokens=4),
            _ev("shed", 41.0, uid=2, tid="queue"),
        ]

    def test_clean_sequence_validates(self):
        assert validate_trace(self._good()) == []

    def test_missing_terminal_flagged(self):
        evs = [e for e in self._good()
               if not (e["name"] == "finish" and e["uid"] == 1)]
        assert any("uid 1" in p and "terminal" in p
                   for p in validate_trace(evs))

    def test_double_terminal_flagged(self):
        evs = self._good() + [_ev("finish", 50.0, uid=1, tid="slot0")]
        assert any("uid 1: 2 terminal" in p for p in validate_trace(evs))

    def test_partial_overlap_flagged(self):
        evs = [_sp("engine_step", 0.0, 10.0),
               _sp("compact", 5.0, 10.0)]      # straddles the step end
        assert any("partially overlaps" in p for p in validate_trace(evs))
        # proper nesting and disjoint siblings both pass
        assert validate_trace([_sp("engine_step", 0.0, 10.0),
                               _sp("compact", 2.0, 3.0),
                               _sp("engine_step", 20.0, 5.0)]) == []

    def test_swap_pairing(self):
        bad = [_sp("resume", 5.0, 1.0, uid=3, tid="slot0")]
        assert any("resume without matching swap_out" in p
                   for p in validate_trace(bad))
        parked = [_sp("swap_out", 1.0, 1.0, uid=3, tid="slot0")]
        assert any("still parked" in p for p in validate_trace(parked))
        # parked-then-shed is a legal end state
        assert validate_trace(parked
                              + [_ev("shed", 9.0, uid=3)]) == []

    def test_totals_reconciliation(self):
        evs = self._good()
        totals = {"sched_swaps_out": 1.0, "sched_swaps_in": 1.0,
                  "sched_sheds": 1.0, "decode_steps": 1.0,
                  "gen_tokens": 7.0}
        assert validate_trace(evs, totals=totals) == []
        assert any("gen_tokens" in p for p in validate_trace(
            evs, totals={**totals, "gen_tokens": 99.0}))
        assert any("decode_steps" in p for p in validate_trace(
            evs, totals={**totals, "decode_steps": 2.0}))

    def test_phase_breakdown(self):
        ph = phase_breakdown([
            _sp("engine_step", 0.0, 1000.0, kind="decode"),
            _sp("engine_step", 2000.0, 3000.0, kind="mixed"),
            _sp("compact", 6000.0, 500.0)])
        assert ph == {"phase_compact_ms": 0.5, "phase_decode_ms": 1.0,
                      "phase_mixed_ms": 3.0}


# ---------------------------------------------------------------------------
# unit: exporters
# ---------------------------------------------------------------------------


class TestExporters:

    def test_chrome_roundtrip(self, tmp_path):
        tr = Tracer()
        tr.begin_serve(100.0, n_shards=2)
        tr.event("queued", tid="queue", uid=4, t=100.0, queue_pos=0)
        tr.span("run", 100.0, 100.5, pid=1, tid="slot3", uid=4, tokens=5)
        tr.event("finish", 100.5, uid=4, tid="slot3", t=100.5)
        evs = tr.finish()
        path = str(tmp_path / "trace.json")
        write_chrome_trace(evs, path, n_shards=2,
                           stats={"gen_tokens": 5.0})
        obj = json.load(open(path))
        assert obj["otherData"]["schema"] == TRACE_SCHEMA
        # metadata names every (pid, tid) track for Perfetto
        meta = {(e["pid"], e["name"]) for e in obj["traceEvents"]
                if e["ph"] == "M"}
        assert (1, "process_name") in meta and (1, "thread_name") in meta
        back = events_from_chrome(obj)
        assert [(e["name"], e["tid"], e["uid"]) for e in back] == \
            [("queued", "queue", 4), ("run", "slot3", 4),
             ("finish", "slot3", 4)]
        assert back[1]["args"]["tokens"] == 5
        assert validate_chrome_file(path) == []

    def test_jsonl_roundtrip(self, tmp_path):
        tr = Tracer()
        tr.begin_serve(0.0)
        tr.span("run", 0.0, 1.0, uid=1, tid="slot0", tokens=2)
        tr.event("finish", t=1.0, uid=1, tid="slot0")
        path = str(tmp_path / "trace.jsonl")
        write_jsonl(tr.finish(), path, meta={"last_stats":
                                             {"gen_tokens": 2.0}})
        assert validate_jsonl_file(path) == []
        bad = str(tmp_path / "bad.jsonl")
        write_jsonl([_sp("run", 0.0, 1.0, uid=9, tid="slot0")], bad)
        assert validate_jsonl_file(bad) != []

    def test_tracer_cap_counts_dropped(self):
        tr = Tracer(max_events=2)
        tr.begin_serve(0.0)
        for i in range(5):
            tr.event("queued", uid=i, t=float(i))
        assert len(tr.events) == 2 and tr.dropped == 3


# ---------------------------------------------------------------------------
# engine: tracing is schedule-invisible and traces validate
# ---------------------------------------------------------------------------


def _scfg(trace, pool_blocks=10):
    return ServerConfig(
        batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
        paged=PagedKVConfig(block_size=4, pool_blocks=pool_blocks),
        use_clustered_batching=False,
        scheduler=SLOConfig(priority_admission=False),
        telemetry=TelemetryConfig(trace=True) if trace else None)


class TestEngineTracing:

    def test_tokens_bit_identical_and_trace_validates(self, params,
                                                      tmp_path):
        """Tracing on vs off under preemption/swap/resume pressure:
        tokens must be bit-identical, and the emitted trace must pass
        every schema invariant AND reconcile against last_stats."""
        reqs, prompts = _mixed_stream()
        off = Server(TINY, _scfg(False), params)
        ref = {o.uid: o.tokens for o in off.serve(reqs, prompts)}
        assert off.last_trace == []            # tracer never constructed

        on = Server(TINY, _scfg(True), params)
        outs = {o.uid: o.tokens for o in on.serve(reqs, prompts)}
        assert outs == ref
        assert on.last_stats["sched_preemptions"] >= 1.0
        evs = on.last_trace
        assert validate_trace(evs, totals=on.last_stats) == []
        names = {e["name"] for e in evs}
        # the lifecycle story is all there, including the swap arc
        for want in ("queued", "run", "first_token", "finish",
                     "engine_step", "prefill_chunk", "swap_out",
                     "resume", "brownout"):
            assert want in names, want
        # brownout events carry the rung and a reason
        br = [e for e in evs if e["name"] == "brownout"]
        assert br and all("rung" in e["args"] and "why" in e["args"]
                          for e in br)
        # exported chrome file validates standalone (CI's check)
        path = str(tmp_path / "trace.json")
        on.export_trace(path)
        assert validate_chrome_file(path) == []
        ph = phase_breakdown(evs)
        assert ph.get("phase_swap_out_ms", 0.0) > 0.0
        assert any(k.startswith("phase_") for k in ph)

    def test_trace_resets_between_serves(self, params):
        srv = Server(TINY, _scfg(True, pool_blocks=48), params)
        reqs, prompts = _mixed_stream(n=3, n_high=0)
        srv.serve(reqs, prompts)
        first = srv.last_trace
        srv.serve(reqs, prompts)
        assert validate_trace(srv.last_trace,
                              totals=srv.last_stats) == []
        assert srv.last_trace is not first


# ---------------------------------------------------------------------------
# engine: stale last_stats keys cannot leak across serves
# ---------------------------------------------------------------------------


class TestStaleStatsRegression:

    def test_dynamic_keys_dropped_between_serves(self, params):
        """Per-serve dynamic keys (template_cluster*, prefix_*) from a
        templated serve must vanish from last_stats once the traffic
        that produced them is gone; lifetime *_total keys persist."""
        scfg = ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=24),
            template_store=TemplateStoreConfig(max_entries=2))
        srv = Server(TINY, scfg, params)
        rng = np.random.default_rng(0)
        tpl = rng.integers(0, 64, size=(16,)).astype(np.int32)
        reqs, prompts = [], {}
        for i in range(4):
            sfx = rng.integers(0, 64, size=(3,))
            prompts[i] = np.concatenate([tpl, sfx]).astype(np.int32)
            reqs.append(Request(i, len(prompts[i]), 4))
        def cid_keys(st):
            # per-cluster keys only: template_cluster<digit>..., not the
            # aggregate template_clusters / template_clusters_retired
            return {k for k in st if k.startswith("template_cluster")
                    and k[len("template_cluster")].isdigit()}

        srv.serve(reqs, prompts)
        srv.serve(reqs, prompts)               # warm serve forms clusters
        st1 = dict(srv.last_stats)
        assert cid_keys(st1)
        hits_total = st1["template_hits_total"]
        assert hits_total >= 1.0

        srv.invalidate_templates()             # template traffic is gone
        reqs2, prompts2 = _mixed_stream(n=3, n_high=0, seed=9)
        srv.serve(reqs2, prompts2)
        st2 = srv.last_stats
        # the invalidated store re-clusters fresh traffic under NEW cids
        # (the cid counter never resets), so serve 3's stats may carry
        # new-cid keys — but every serve-2-era cid key is stale and must
        # be gone, and the keys present must mirror the live clusters
        live = {int(c["cid"]) for c in srv._store.cluster_stats()[:8]}
        got = cid_keys(st2)
        want = {f"template_cluster{cid}_{sfx}" for cid in live
                for sfx in ("cohesion", "hit_rate", "bytes_pinned")}
        assert got == want
        assert not (got & cid_keys(st1))
        # lifetime totals survive the per-serve drop, monotonically
        assert st2["template_hits_total"] >= hits_total

    def test_sched_keys_absent_without_scheduler(self, params):
        """A scheduler-less server built after a scheduled one shares no
        registry, and a single server never leaks sched_* keys into a
        serve that has no scheduler — the per-server config is fixed, so
        the cross-serve hazard is per-serve dynamic keys only (covered
        above); here: the baseline absence contract still holds."""
        reqs, prompts = _mixed_stream(n=3, n_high=0)
        srv = Server(TINY, ServerConfig(
            batch_size=2, max_seq=96, kv_compress=CCFG, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4)), params)
        srv.serve(reqs, prompts)
        assert not any(k.startswith("sched_") for k in srv.last_stats)
        assert not any(k.startswith("template_") for k in srv.last_stats)


class TestMetricsReference:
    """The committed metrics reference (docs/metrics.md) is generated
    from the live registrations via `python -m repro.runtime.telemetry
    reference` — this pins it fresh so a new or renamed metric cannot
    ship undocumented."""

    def test_docs_metrics_md_up_to_date(self):
        import pathlib
        from repro.runtime.telemetry import reference_doc
        root = pathlib.Path(__file__).resolve().parent.parent
        path = root / "docs" / "metrics.md"
        assert path.exists(), "docs/metrics.md missing — generate with " \
            "`python -m repro.runtime.telemetry reference > docs/metrics.md`"
        doc = reference_doc()
        assert path.read_text() == doc, \
            "docs/metrics.md is stale — regenerate with " \
            "`python -m repro.runtime.telemetry reference > docs/metrics.md`"

    def test_reference_covers_recurrent_family_metrics(self):
        """The layer-state refactor's new always-present metrics are in
        the reference (and therefore in the committed docs)."""
        from repro.runtime.telemetry import reference_registry
        names = set(reference_registry()._metrics)
        for key in ("kv_retired_recurrent", "state_bytes_ring",
                    "state_bytes_recurrent", "sched_swap_bytes"):
            assert key in names, key
