"""Clustering engine tests: Lloyd convergence, robustness, paper protocols."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering
from repro.core.clustering import ClusterConfig


def make_blobs(rng, n_per, centers, std=0.3):
    centers = np.asarray(centers, np.float32)
    k, d = centers.shape
    xs, ys = [], []
    for c in range(k):
        xs.append(rng.normal(size=(n_per, d)).astype(np.float32) * std + centers[c])
        ys.append(np.full((n_per,), c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


CENTERS = [[0.0, 0.0], [5.0, 5.0], [-5.0, 5.0], [5.0, -5.0]]


class TestFit:
    @pytest.mark.parametrize("centroid,metric", [("mean", "l2"), ("median", "l1")])
    def test_recovers_blobs(self, centroid, metric):
        rng = np.random.default_rng(0)
        x, y = make_blobs(rng, 64, CENTERS)
        cfg = ClusterConfig(k=4, centroid=centroid, metric=metric, seed=3)
        res = clustering.fit(jnp.asarray(x), cfg)
        rate = clustering.recognition_rate(res.assign, jnp.asarray(y), 4, 4)
        assert float(rate) > 0.97, f"recognition {float(rate)}"
        assert int(res.n_iters) < cfg.max_iters

    def test_median_robust_to_outliers_vs_mean(self):
        rng = np.random.default_rng(1)
        x, _ = make_blobs(rng, 100, [[0.0, 0.0]], std=0.2)
        x[:5] = 1000.0  # gross outliers
        init = jnp.asarray([[0.5, 0.5]], jnp.float32)
        cfg_med = ClusterConfig(k=1, centroid="median", metric="l1", max_iters=5)
        cfg_mean = ClusterConfig(k=1, centroid="mean", metric="l2", max_iters=5)
        cm = clustering.fit(jnp.asarray(x), cfg_med, init).centroids
        ca = clustering.fit(jnp.asarray(x), cfg_mean, init).centroids
        err_med = float(jnp.abs(cm).max())
        err_mean = float(jnp.abs(ca).max())
        assert err_med < 0.2, err_med         # median ignores outliers
        assert err_mean > 5.0, err_mean       # mean is dragged away

    def test_convergence_flag_and_inertia_decreases(self):
        rng = np.random.default_rng(2)
        x, _ = make_blobs(rng, 50, CENTERS)
        cfg = ClusterConfig(k=4, centroid="mean", metric="l2", max_iters=1)
        r1 = clustering.fit(jnp.asarray(x), cfg)
        cfg50 = dataclasses.replace(cfg, max_iters=50)
        r50 = clustering.fit(jnp.asarray(x), cfg50)
        assert float(r50.inertia) <= float(r1.inertia) + 1e-3

    def test_jit_fit(self):
        rng = np.random.default_rng(3)
        x, _ = make_blobs(rng, 32, CENTERS)
        from functools import partial
        f = jax.jit(partial(clustering.fit, cfg=ClusterConfig(k=4)))
        res = f(jnp.asarray(x))
        assert res.centroids.shape == (4, 2)
        assert not bool(jnp.isnan(res.centroids).any())


class TestMiniBatch:
    def test_minibatch_converges(self):
        rng = np.random.default_rng(4)
        x, y = make_blobs(rng, 256, CENTERS)
        res = clustering.fit_minibatch(
            jax.random.PRNGKey(0), jnp.asarray(x),
            ClusterConfig(k=4, centroid="median", metric="l1"),
            batch_size=128, n_steps=30)
        rate = clustering.recognition_rate(res.assign, jnp.asarray(y), 4, 4)
        assert float(rate) > 0.9


class TestModelSelection:
    def test_select_k_finds_true_k(self):
        rng = np.random.default_rng(5)
        x, _ = make_blobs(rng, 60, CENTERS, std=0.25)
        k_opt, scores = clustering.select_k(jnp.asarray(x), 2, 6,
                                            ClusterConfig(k=2, centroid="mean",
                                                          metric="l2"))
        assert k_opt == 4, (k_opt, scores)

    def test_recognition_rate_perfect_and_chance(self):
        assign = jnp.asarray([0, 0, 1, 1], jnp.int32)
        labels = jnp.asarray([1, 1, 0, 0], jnp.int32)
        assert float(clustering.recognition_rate(assign, labels, 2, 2)) == 1.0


class TestAssignment:
    def test_kernel_vs_jnp_paths_agree(self):
        rng = np.random.default_rng(6)
        x = rng.normal(size=(300, 8)).astype(np.float32)
        c = rng.normal(size=(5, 8)).astype(np.float32)
        for metric in ("l1", "l2"):
            a1, m1 = clustering.assign_points(jnp.asarray(x), jnp.asarray(c),
                                              metric, use_kernel=True)
            a2, m2 = clustering.assign_points(jnp.asarray(x), jnp.asarray(c),
                                              metric, use_kernel=False)
            np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
            np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                                       rtol=1e-4, atol=1e-4)

    def test_chunked_assignment(self):
        rng = np.random.default_rng(7)
        x = rng.normal(size=(1000, 4)).astype(np.float32)
        c = rng.normal(size=(3, 4)).astype(np.float32)
        a, m = clustering._assign_points_jnp(jnp.asarray(x), jnp.asarray(c),
                                             "l2", chunk=256)
        from repro.kernels import ref
        ea, em = ref.distance_argmin_ref(x, c, "l2")
        np.testing.assert_array_equal(np.asarray(a), ea)
        np.testing.assert_allclose(np.asarray(m), em, rtol=1e-4, atol=1e-4)
