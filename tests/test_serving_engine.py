"""Continuous-batching serving engine tests.

  * parity: the slot-based continuous batcher emits every request's exact
    greedy tokens (vs a one-at-a-time static decode — no cross-request
    contamination from shared slots, ragged positions, or bucket padding),
  * mid-stream clustered-KV compaction preserves outputs within tolerance
    and keeps completions well-formed,
  * the batched (vmap over batch ⊕ head) compress_cache matches an
    explicit per-(batch, head) Python loop on identical inputs/weights,
  * incremental re-compaction conserves summary mass and advances the
    coverage frontier monotonically.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kv_compress
from repro.core.request_cluster import Request
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.server import Server, ServerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")


@pytest.fixture(scope="module")
def pieces():
    params = tfm.init_params(jax.random.PRNGKey(0), TINY)
    rng = np.random.default_rng(0)
    reqs = [Request(i, int(l), g) for i, (l, g) in
            enumerate([(5, 4), (23, 6), (9, 3), (17, 5), (6, 1), (21, 4)])]
    prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    ref = Server(TINY, ServerConfig(batch_size=1, max_seq=64,
                                    engine="static",
                                    use_clustered_batching=False), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    return params, reqs, prompts, ref_out


class TestContinuousEngine:
    def test_exact_greedy_parity(self, pieces):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        # per-request early exit: each slot stopped at its own budget
        for o in outs:
            assert len(o.tokens) == reqs[o.uid].max_new_tokens
        assert srv.last_stats["gen_tokens"] == sum(
            r.max_new_tokens for r in reqs)

    def test_parity_independent_of_slot_count_and_bucket(self, pieces):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(batch_size=3, max_seq=64,
                                        prefill_bucket=8,
                                        use_clustered_batching=False),
                     params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], o.uid

    def test_compaction_midstream_preserves_output(self, pieces):
        params, reqs, prompts, ref_out = pieces
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=16, refresh_every=8)
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        agree = []
        for o in outs:
            assert len(o.tokens) == reqs[o.uid].max_new_tokens
            assert all(0 <= t < TINY.padded_vocab for t in o.tokens)
            agree.append(np.mean(np.array(o.tokens)
                                 == np.array(ref_out[o.uid])))
        assert np.mean(agree) > 0.7, agree

    def test_sliding_window_layers_stay_exact_under_compaction(self):
        """compact_kv must never clusterize an 'L' ring buffer (only the
        leaves a clustered-mode cache holds in clustered form), and the
        engine must admit at exact prompt length for windowed models —
        bucket padding would enter the ring at wrong claimed positions."""
        cfg = ModelConfig(name="tiny-gl", family="dense", n_layers=2,
                          d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64, vocab=64, pad_vocab_multiple=16,
                          dtype="float32", layer_pattern="GL",
                          sliding_window=16)
        params = tfm.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(4)
        reqs = [Request(i, int(l), 6) for i, l in enumerate([30, 12, 25])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        ref = Server(cfg, ServerConfig(batch_size=1, max_seq=64,
                                      engine="static",
                                      use_clustered_batching=False), params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}

        # exact continuous serving: parity must hold despite prefill_bucket
        # (the engine forces bucket 1 for windowed models)
        srv_e = Server(cfg, ServerConfig(batch_size=2, max_seq=64,
                                         prefill_bucket=16), params)
        for o in srv_e.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], o.uid

        ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                            keep_recent=8, refresh_every=4)
        srv = Server(cfg, ServerConfig(batch_size=2, max_seq=64,
                                       kv_compress=ccfg), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == [0, 1, 2]
        for o in outs:
            assert len(o.tokens) == 6
            assert all(0 <= t < cfg.padded_vocab for t in o.tokens)

    def test_refresh_interval_validated(self, pieces):
        params = pieces[0]
        ccfg = kv_compress.KVCompressConfig(keep_recent=16, refresh_every=0)
        with pytest.raises(ValueError, match="refresh_every"):
            Server(TINY, ServerConfig(kv_compress=ccfg), params)


class TestBatchedCompress:
    def test_matches_per_head_loop(self):
        rng = np.random.default_rng(1)
        B, S, H, Dh = 2, 96, 2, 16
        k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        lengths = jnp.asarray([96, 80], jnp.int32)
        cfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                           keep_recent=16, refresh_every=8)
        cc = kv_compress.compress_cache_batched(k, v, lengths, cfg)
        np.testing.assert_array_equal(np.asarray(cc["cov"]), [88, 72])
        for b in range(B):
            cov_b = int(np.asarray(cc["cov"])[b])
            w_b = (jnp.arange(S) < cov_b).astype(jnp.float32)
            for h in range(H):
                kc, vc, cnt = kv_compress.compress_head(
                    k[b, :, h], v[b, :, h], cfg, weights=w_b)
                np.testing.assert_allclose(
                    np.asarray(cc["k_cents"][b, :, h]), np.asarray(kc),
                    rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(cc["v_cents"][b, :, h]), np.asarray(vc),
                    rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(cc["counts"][b, :, h]), np.asarray(cnt),
                    rtol=1e-4, atol=1e-4)

    def test_tail_ring_layout(self):
        rng = np.random.default_rng(2)
        S, H, Dh = 64, 1, 8
        k = jnp.asarray(rng.normal(size=(1, S, H, Dh)), jnp.float32)
        cfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                           keep_recent=8, refresh_every=4)
        cc = kv_compress.compress_cache_batched(
            k, k, jnp.asarray([50]), cfg)
        # position p lives at ring slot p % R: check position 47 (slot 7)
        np.testing.assert_allclose(np.asarray(cc["k_tail"][0, 47 % 8, 0]),
                                   np.asarray(k[0, 47, 0]), rtol=1e-6)

    def test_recompact_conserves_and_advances(self):
        rng = np.random.default_rng(3)
        B, S, H, Dh = 2, 96, 2, 16
        k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        lengths = jnp.asarray([96, 80], jnp.int32)
        cfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                           keep_recent=16, refresh_every=8)
        cc = kv_compress.compress_cache_batched(k, v, lengths, cfg)
        cc2 = kv_compress.recompact_clustered(cc, lengths + 8, cfg)
        cov1, cov2 = np.asarray(cc["cov"]), np.asarray(cc2["cov"])
        assert (cov2 >= cov1).all()
        # total summarized mass == number of covered positions, per slot
        m1 = np.asarray(cc["counts"]).sum(axis=(1, 2))
        m2 = np.asarray(cc2["counts"]).sum(axis=(1, 2))
        h = np.asarray(cc["counts"]).shape[2]
        np.testing.assert_allclose(m1, cov1 * h, rtol=1e-5)
        np.testing.assert_allclose(m2, cov2 * h, rtol=1e-5)
