"""Continuous-batching serving engine tests.

  * parity: the slot-based continuous batcher emits every request's exact
    greedy tokens (vs a one-at-a-time static decode — no cross-request
    contamination from shared slots, ragged positions, or bucket padding),
  * mid-stream clustered-KV compaction preserves outputs within tolerance
    and keeps completions well-formed,
  * the batched (vmap over batch ⊕ head) compress_cache matches an
    explicit per-(batch, head) Python loop on identical inputs/weights,
  * incremental re-compaction conserves summary mass and advances the
    coverage frontier monotonically.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kv_compress
from repro.core.request_cluster import Request
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.kv_pool import PagedKVConfig, PoolExhausted
from repro.runtime.server import Server, ServerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                   pad_vocab_multiple=16, dtype="float32")


@pytest.fixture(scope="module")
def pieces():
    params = tfm.init_params(jax.random.PRNGKey(0), TINY)
    rng = np.random.default_rng(0)
    reqs = [Request(i, int(l), g) for i, (l, g) in
            enumerate([(5, 4), (23, 6), (9, 3), (17, 5), (6, 1), (21, 4)])]
    prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    ref = Server(TINY, ServerConfig(batch_size=1, max_seq=64,
                                    engine="static",
                                    use_clustered_batching=False), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    return params, reqs, prompts, ref_out


class TestContinuousEngine:
    def test_exact_greedy_parity(self, pieces):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        # per-request early exit: each slot stopped at its own budget
        for o in outs:
            assert len(o.tokens) == reqs[o.uid].max_new_tokens
        assert srv.last_stats["gen_tokens"] == sum(
            r.max_new_tokens for r in reqs)

    def test_parity_independent_of_slot_count_and_bucket(self, pieces):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(batch_size=3, max_seq=64,
                                        prefill_bucket=8,
                                        use_clustered_batching=False),
                     params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], o.uid

    def test_compaction_midstream_preserves_output(self, pieces):
        params, reqs, prompts, ref_out = pieces
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=16, refresh_every=8)
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        agree = []
        for o in outs:
            assert len(o.tokens) == reqs[o.uid].max_new_tokens
            assert all(0 <= t < TINY.padded_vocab for t in o.tokens)
            agree.append(np.mean(np.array(o.tokens)
                                 == np.array(ref_out[o.uid])))
        assert np.mean(agree) > 0.7, agree

    def test_sliding_window_layers_stay_exact_under_compaction(self):
        """compact_kv must never clusterize an 'L' ring buffer (only the
        leaves a clustered-mode cache holds in clustered form), and the
        engine must admit at exact prompt length for windowed models —
        bucket padding would enter the ring at wrong claimed positions."""
        cfg = ModelConfig(name="tiny-gl", family="dense", n_layers=2,
                          d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64, vocab=64, pad_vocab_multiple=16,
                          dtype="float32", layer_pattern="GL",
                          sliding_window=16)
        params = tfm.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(4)
        reqs = [Request(i, int(l), 6) for i, l in enumerate([30, 12, 25])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        ref = Server(cfg, ServerConfig(batch_size=1, max_seq=64,
                                      engine="static",
                                      use_clustered_batching=False), params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}

        # exact continuous serving: parity must hold despite prefill_bucket
        # (the engine forces bucket 1 for windowed models)
        srv_e = Server(cfg, ServerConfig(batch_size=2, max_seq=64,
                                         prefill_bucket=16), params)
        for o in srv_e.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], o.uid

        ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                            keep_recent=8, refresh_every=4)
        srv = Server(cfg, ServerConfig(batch_size=2, max_seq=64,
                                       kv_compress=ccfg), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == [0, 1, 2]
        for o in outs:
            assert len(o.tokens) == 6
            assert all(0 <= t < cfg.padded_vocab for t in o.tokens)

    def test_refresh_interval_validated(self, pieces):
        params = pieces[0]
        ccfg = kv_compress.KVCompressConfig(keep_recent=16, refresh_every=0)
        with pytest.raises(ValueError, match="refresh_every"):
            Server(TINY, ServerConfig(kv_compress=ccfg), params)


class TestChunkedPrefill:
    """Chunked prefill interleaved with decode: admission streams the
    prompt through mixed-mode decode steps instead of a blocking prefill
    call — greedy outputs must stay token-identical to the blocking path
    on the exact-KV engine (same math, different schedule)."""

    @pytest.mark.parametrize("chunk", [4, 16])
    def test_token_identical_to_blocking(self, pieces, chunk):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        prefill_chunk=chunk), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        st = srv.last_stats
        assert st["prefill_chunks"] > 0
        assert st["prefill_pad_frac"] == 0.0      # exact positions, no pad
        assert st["ttft_p95_ms"] > 0 and st["itl_p50_ms"] >= 0

    def test_clustered_short_prompts_token_identical(self, pieces):
        """Prompts that fit the tail ring admit loss-free in both modes
        (tail-only form == streamed ring writes), so even the clustered
        engine stays token-identical while no absorb is needed."""
        params, reqs, prompts, ref_out = pieces
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=32, refresh_every=4)
        ref = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg), params)
        ref_c = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg, prefill_chunk=8),
                     params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_c[o.uid], o.uid
        assert srv.last_stats["kv_absorbs"] == 0.0

    def test_long_prompt_streams_through_absorb(self, pieces):
        """A prompt longer than the tail ring must be admitted in
        clustered form via absorb_chunk (compaction-aware admission) and
        still decode sanely, agreeing with the blocking clustered path."""
        params = pieces[0]
        rng = np.random.default_rng(9)
        reqs = [Request(i, int(l), g) for i, (l, g) in
                enumerate([(60, 6), (9, 4), (48, 5)])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=16, refresh_every=8)
        ref = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg), params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64,
                                        kv_compress=ccfg, prefill_chunk=8),
                     params)
        outs = srv.serve(reqs, prompts)
        assert srv.last_stats["kv_absorbs"] > 0
        agree = []
        for o in outs:
            assert len(o.tokens) == reqs[o.uid].max_new_tokens
            assert all(0 <= t < TINY.padded_vocab for t in o.tokens)
            agree.append(np.mean(np.array(o.tokens)
                                 == np.array(ref_out[o.uid])))
        # streamed absorption vs whole-prompt batch k-medians differ only
        # in centroid placement; greedy tokens should rarely flip
        assert np.mean(agree) > 0.7, agree

    def test_rejects_unsupported_models(self, pieces):
        """The gate is per-(layer, kind) now: sliding-window 'L' layers
        serve chunked (WindowRetention) and recurrent 'M'/'R' layers
        serve as checkpointed fixed-size state (RecurrentRetention), so
        rejection happens only for state no family covers — and the
        diagnostic names each offending layer index and its kind."""
        params = pieces[0]
        import dataclasses as dc
        # 'L' without sliding_window has no window to retire behind
        gl = dc.replace(TINY, layer_pattern="GL")
        with pytest.raises(ValueError, match="without sliding_window"):
            Server(gl, ServerConfig(prefill_chunk=8), params)
        # recurrent sub-layers are a supported family now: the gate must
        # NOT fire for a 'GR' pattern (the serve itself is pinned in
        # TestRecurrentServing)
        gr = dc.replace(TINY, layer_pattern="GR", lru_width=32)
        assert gr.serving_gate_report() is None
        Server(gr, ServerConfig(prefill_chunk=8), params)
        ccfg = kv_compress.KVCompressConfig(keep_recent=8, refresh_every=4)
        with pytest.raises(ValueError, match="keep_recent"):
            Server(TINY, ServerConfig(prefill_chunk=16, kv_compress=ccfg),
                   params)

    def test_gate_report_enumerates_every_gap(self):
        """Regression: the report used to stop at the first blocking
        layer — a mixed config's diagnostics must name EVERY unsupported
        (layer, kind) pair at once, alongside any config-level gaps."""
        import dataclasses as dc
        # windowless 'L' at layers 1, 3, 5 — all three must be named
        gl = dc.replace(TINY, n_layers=6, layer_pattern="GL")
        report = gl.serving_gate_report()
        for i in (1, 3, 5):
            assert f"layer {i}: local attention without sliding_window" \
                in report, report
        # unknown kind + windowless 'L' together: both enumerated, with
        # per-layer indices and the closing statement of the rule
        weird = dc.replace(TINY, n_layers=4, layer_pattern="GLXG")
        report = weird.serving_gate_report()
        assert "layer 1: local attention without sliding_window" in report
        assert "layer 2: unknown kind 'X' has no layer-state family" \
            in report
        assert "recurrent-state layers" in report
        # config-level gaps (MLA) combine with per-layer gaps in one pass
        mla = dc.replace(TINY, n_layers=2, layer_pattern="GL",
                         attn_kind="mla")
        report = mla.serving_gate_report()
        assert "latent KV" in report
        assert "layer 1: local attention without sliding_window" in report
        # supported kinds never appear as problems
        ok = dc.replace(TINY, layer_pattern="GL", sliding_window=8)
        assert ok.serving_gate_report() is None


class TestBucketedLaunch:
    """Bucketed decode launches: the drain tail shrinks the physical
    batch (powers of two per data shard) without changing outputs."""

    def test_drain_shrinks_launch_and_keeps_tokens(self, pieces):
        params, _, _, _ = pieces
        rng = np.random.default_rng(4)
        # one straggler keeps decoding long after the others exit, so the
        # drain walks the bucket down to 1 slot
        reqs = [Request(0, 9, 40)] + [
            Request(i, int(rng.integers(5, 20)), 3) for i in range(1, 6)]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        ref = Server(TINY, ServerConfig(batch_size=1, max_seq=64,
                                        engine="static",
                                        use_clustered_batching=False),
                     params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=4, max_seq=64), params)
        outs = srv.serve(reqs, prompts)
        st = srv.last_stats
        assert st["launch_rows_frac"] < 1.0, st
        assert st["launch_bucket_mean"] < 4.0
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid

    def test_uniform_occupancy_never_shrinks(self, pieces):
        params = pieces[0]
        rng = np.random.default_rng(8)
        # identical budgets on a full batch: every slot is busy until the
        # same final step, so no launch is ever smaller than the batch
        reqs = [Request(i, 7, 5) for i in range(2)]
        prompts = {r.uid: rng.integers(0, 64, size=(7,)).astype(np.int32)
                   for r in reqs}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=64), params)
        srv.serve(reqs, prompts)
        assert srv.last_stats["launch_rows_frac"] == 1.0


class TestPagedEngine:
    """Paged clustered-KV memory manager: block-pool tail rings behind
    per-slot block tables, decoded via packed ragged launches.  The paged
    engine must emit greedy tokens BIT-IDENTICAL to the dense clustered
    engine (same ccfg, same queue) — the pool only changes where tail
    bytes live, and the packed kernel reproduces the dense kernel's math
    exactly — across blocking and chunked admission, with mid-stream
    compaction and streaming absorbs in play."""

    CCFG = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    PG = PagedKVConfig(block_size=4)

    @staticmethod
    def _stream(seed=9):
        rng = np.random.default_rng(seed)
        # long prompts (> keep_recent → absorbs under chunked admission)
        # and long budgets (> refresh_every → mid-stream compactions)
        reqs = [Request(i, int(l), g) for i, (l, g) in
                enumerate([(60, 12), (9, 10), (48, 9), (21, 14)])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        return reqs, prompts

    @pytest.mark.parametrize("chunk", [0, 8])
    def test_token_identical_to_dense(self, pieces, chunk):
        params = pieces[0]
        reqs, prompts = self._stream()
        dense = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                          kv_compress=self.CCFG,
                                          prefill_chunk=chunk), params)
        ref = {o.uid: o.tokens for o in dense.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=self.CCFG,
                                        prefill_chunk=chunk, paged=self.PG),
                     params)
        outs = srv.serve(reqs, prompts)
        for o in outs:
            assert o.tokens == ref[o.uid], o.uid
        st = srv.last_stats
        assert st["kv_compactions"] > 0       # the paths really diverged
        if chunk:
            assert st["kv_absorbs"] > 0
        # every block recycled once the stream drains
        assert st["pool_blocks_end"] == 0.0
        assert 0.0 < st["pool_occupancy_peak"] <= 1.0

    def test_packed_launch_beats_dense_padding(self, pieces):
        """Mixed prefill+decode compute ∝ real tokens: the packed ragged
        launch must waste strictly less padded compute than the dense
        bucketed launch on the same chunked stream, at identical
        tokens."""
        params = pieces[0]
        reqs, prompts = self._stream()
        dense = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                          kv_compress=self.CCFG,
                                          prefill_chunk=8), params)
        dense.serve(reqs, prompts)
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=self.CCFG,
                                        prefill_chunk=8, paged=self.PG),
                     params)
        srv.serve(reqs, prompts)
        assert (srv.last_stats["launch_pad_frac"]
                < dense.last_stats["launch_pad_frac"])
        assert srv.last_stats["launch_ragged_frac"] > \
            dense.last_stats["launch_ragged_frac"]
        # the pool never allocates beyond the dense ring (it may touch it
        # transiently when every slot is at full depth at a compaction
        # boundary), and allocation tracks live tokens tighter than the
        # always-full dense ring does
        assert (srv.last_stats["kv_bytes_peak_per_shard"]
                <= dense.last_stats["kv_bytes_peak_per_shard"])
        assert srv.last_stats["kv_frag"] < dense.last_stats["kv_frag"]

    def test_blocks_recycle_and_reallocate(self, pieces):
        """Compaction give-back and slot recycling really return blocks:
        total allocations exceed the peak simultaneously live (blocks
        were freed and handed out again), and the pool drains to zero."""
        params = pieces[0]
        reqs, prompts = self._stream()
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=self.CCFG,
                                        prefill_chunk=8, paged=self.PG),
                     params)
        srv.serve(reqs, prompts)
        st = srv.last_stats
        assert st["pool_allocs"] > st["pool_blocks_peak"]
        assert st["pool_frees"] == st["pool_allocs"]      # all returned

    def test_oversubscribed_pool_serves_short_streams(self, pieces):
        """A pool smaller than slots × blocks-per-slot still serves when
        live windows stay short (blocks map lazily, only live positions
        hold storage); a pool too small for even serialized live windows
        on a deep stream still raises PoolExhausted — but only at
        genuine zero forward progress (every slot stalled, nothing
        reclaimable), after admission deferral and per-slot write stalls
        have been exhausted."""
        params = pieces[0]
        rng = np.random.default_rng(3)
        # every request's final depth <= 8 positions -> <= 2 live blocks
        # per slot, so 5 blocks serve 2 slots that would dense-allocate 8
        short = [Request(i, int(l), g) for i, (l, g) in
                 enumerate([(5, 3), (4, 2), (6, 2), (5, 3), (4, 2)])]
        sp = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in short}
        dense = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                          kv_compress=self.CCFG), params)
        ref = {o.uid: o.tokens for o in dense.serve(short, sp)}
        srv = Server(TINY, ServerConfig(
            batch_size=2, max_seq=96, kv_compress=self.CCFG,
            paged=PagedKVConfig(block_size=4, pool_blocks=5)), params)
        for o in srv.serve(short, sp):
            assert o.tokens == ref[o.uid], o.uid
        assert srv.last_stats["pool_occupancy_peak"] <= 1.0
        reqs, prompts = self._stream()
        with pytest.raises(PoolExhausted):
            tight = Server(TINY, ServerConfig(
                batch_size=2, max_seq=96, kv_compress=self.CCFG,
                paged=PagedKVConfig(block_size=4, pool_blocks=4)), params)
            tight.serve(reqs, prompts)

    def test_validation(self, pieces):
        params = pieces[0]
        # paged WITHOUT kv_compress is legal now (QuotaRetention exact
        # KV) but whole blocks must tile the full sequence depth
        with pytest.raises(ValueError, match="max_seq"):
            Server(TINY, ServerConfig(
                max_seq=30, paged=PagedKVConfig(block_size=4)), params)
        with pytest.raises(ValueError, match="block_size"):
            Server(TINY, ServerConfig(
                kv_compress=self.CCFG,
                paged=PagedKVConfig(block_size=5)), params)
        # per-layer gate: MLA latent caches have no retention policy
        import dataclasses as dc
        mla = dc.replace(TINY, attn_kind="mla")
        with pytest.raises(ValueError, match="latent KV"):
            Server(mla, ServerConfig(kv_compress=self.CCFG, paged=self.PG),
                   params)


GLWIN = ModelConfig(name="tiny-gl", family="dense", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                    pad_vocab_multiple=16, dtype="float32",
                    layer_pattern="GL", sliding_window=16)


class TestWindowedServing:
    """Sliding-window models under the retention-policy layer: 'L' layers
    retire behind WindowRetention while 'G' layers stay clustered behind
    FrontierRetention — chunked admission (dense AND paged) must emit
    greedy tokens BIT-IDENTICAL to blocking dense admission, because the
    staged per-layer ring writes never evict an in-window entry."""

    # prompts fit the tail ring (loss-free admission in both modes) but
    # exceed the 16-token window, and budgets push positions past
    # keep_recent so compactions advance the 'G' frontier mid-decode
    CCFG = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                        keep_recent=32, refresh_every=4)

    @staticmethod
    def _stream(seed=13):
        rng = np.random.default_rng(seed)
        reqs = [Request(i, int(l), g) for i, (l, g) in
                enumerate([(26, 10), (12, 6), (20, 8), (8, 5)])]
        prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
            np.int32) for r in reqs}
        return reqs, prompts

    @pytest.fixture(scope="class")
    def win_pieces(self):
        params = tfm.init_params(jax.random.PRNGKey(7), GLWIN)
        reqs, prompts = self._stream()
        ref = Server(GLWIN, ServerConfig(batch_size=2, max_seq=64,
                                         kv_compress=self.CCFG), params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        assert ref.last_stats["kv_retired_window"] > 0
        return params, reqs, prompts, ref_out

    def test_chunked_dense_token_identical_to_blocking(self, win_pieces):
        params, reqs, prompts, ref_out = win_pieces
        srv = Server(GLWIN, ServerConfig(batch_size=2, max_seq=64,
                                         kv_compress=self.CCFG,
                                         prefill_chunk=8), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        st = srv.last_stats
        # both policies really retired entries: windows slid past 16
        # positions and compactions advanced the clustered frontier
        assert st["kv_retired_window"] > 0
        assert st["kv_retired_frontier"] > 0
        assert st["prefill_chunks"] > 0

    def test_chunked_paged_token_identical_to_blocking(self, win_pieces):
        params, reqs, prompts, ref_out = win_pieces
        srv = Server(GLWIN, ServerConfig(
            batch_size=2, max_seq=64, kv_compress=self.CCFG,
            prefill_chunk=8, paged=PagedKVConfig(block_size=4)), params)
        outs = srv.serve(reqs, prompts)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        st = srv.last_stats
        assert st["kv_retired_window"] > 0
        assert st["kv_retired_frontier"] > 0
        assert st["pool_blocks_end"] == 0.0


class TestQuotaRetention:
    """Paged serving WITHOUT kv_compress: exact KV under QuotaRetention.
    Admission reserves the request's whole block budget up front
    (admitted => completable), nothing retires mid-flight, and blocks
    return only at request exit — so an oversubscribed pool defers
    admissions instead of raising PoolExhausted, at greedy tokens
    identical to the dense exact engine."""

    def test_exact_paged_oversubscribed_burst(self, pieces):
        params, reqs, prompts, ref_out = pieces
        # 8 blocks < the 13-block peak two full requests would need
        # concurrently: the second admission must defer until the first
        # exits, yet every request still completes with exact tokens
        srv = Server(TINY, ServerConfig(
            batch_size=2, max_seq=64,
            paged=PagedKVConfig(block_size=4, pool_blocks=8)), params)
        outs = srv.serve(reqs, prompts)
        assert sorted(o.uid for o in outs) == sorted(r.uid for r in reqs)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        st = srv.last_stats
        assert st["kv_retired_quota"] > 0
        assert st["kv_retired_frontier"] == 0.0   # nothing clustered
        assert st["pool_blocks_end"] == 0.0
        assert st["pool_occupancy_peak"] <= 1.0

    def test_chunked_quota_admission(self, pieces):
        params, reqs, prompts, ref_out = pieces
        srv = Server(TINY, ServerConfig(
            batch_size=2, max_seq=64, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=8)), params)
        outs = srv.serve(reqs, prompts)
        for o in outs:
            assert o.tokens == ref_out[o.uid], o.uid
        assert srv.last_stats["kv_retired_quota"] > 0
        assert srv.last_stats["pool_blocks_end"] == 0.0


class TestPrefixSharing:
    """Prefix-shared paged admission (ServerConfig.prefix_share): chunked
    admissions register prefix-pure state (tail blocks + absorbed
    centroids + frontier) at chunk boundaries; later same-prefix requests
    adopt the blocks (copy-on-write) and restore the state.  Greedy
    tokens must be BIT-IDENTICAL to unshared paged serving — the reused
    state is exactly what the unshared run recomputes from the same
    prefix tokens, and per-slot compaction cadence + the
    recompact_clustered no-advance gate make every slot's stream
    schedule-independent."""

    PG = PagedKVConfig(block_size=4)

    @staticmethod
    def _template_stream(n=6, tpl_len=40, seed=5):
        """Bursty templated traffic: one shared template + short unique
        suffixes, everything queued at t0."""
        rng = np.random.default_rng(seed)
        template = rng.integers(0, 64, size=(tpl_len,)).astype(np.int32)
        reqs, prompts = [], {}
        for i in range(n):
            sfx = rng.integers(0, 64,
                               size=(int(rng.integers(3, 9)),)).astype(
                                   np.int32)
            prompts[i] = np.concatenate([template, sfx])
            reqs.append(Request(i, len(prompts[i]),
                                int(rng.integers(6, 12))))
        return reqs, prompts

    # refresh 8: compactions fire mid-stream (token budgets reach 11);
    # refresh 12: no slot ever hits the cadence — the ± compaction pair
    @pytest.mark.parametrize("refresh", [8, 12])
    def test_token_identical_to_unshared(self, pieces, refresh):
        from repro.runtime.prefix_cache import PrefixShareConfig
        params = pieces[0]
        reqs, prompts = self._template_stream()
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=16,
                                            refresh_every=refresh)
        base = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                         kv_compress=ccfg, prefill_chunk=8,
                                         paged=self.PG), params)
        ref = {o.uid: o.tokens for o in base.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=ccfg, prefill_chunk=8,
                                        paged=self.PG,
                                        prefix_share=PrefixShareConfig()),
                     params)
        outs = srv.serve(reqs, prompts)
        for o in outs:
            assert o.tokens == ref[o.uid], o.uid
        st = srv.last_stats
        # sharing really happened: admissions hit the cache, skipped
        # feeding prefix chunks, shared physical blocks, and COW fired
        # when divergent suffixes wrote into shared blocks
        assert st["prefix_hits"] > 0
        assert st["prefix_tokens_reused"] > 0
        assert st["kv_shared_blocks"] > 0 and st["kv_bytes_saved"] > 0
        # skipped prefix chunks = less prompt compute than unshared
        assert st["prefill_chunks"] < base.last_stats["prefill_chunks"]
        # every shared/retained block released at drain
        assert st["pool_blocks_end"] == 0.0
        if refresh == 8:
            assert st["kv_compactions"] > 0
            # divergent suffixes wrote into shared blocks → COW fired
            # (at refresh 12 the live window is too short for writes to
            # reach retained blocks, so sharing never needs a copy)
            assert st["pool_cow"] > 0

    def test_long_suffixes_still_hit_the_template_entry(self, pieces):
        """Suffixes LONGER than a chunk: each stream registers chunk
        boundaries inside its own unique suffix, but the pure-template
        boundary entry must survive (shorter prefixes are never evicted
        by longer registrations of the same stream) so every later
        same-template request still hits it — tokens bit-identical to
        unshared throughout."""
        from repro.runtime.prefix_cache import PrefixShareConfig
        params = pieces[0]
        rng = np.random.default_rng(11)
        template = rng.integers(0, 64, size=(24,)).astype(np.int32)
        reqs, prompts = [], {}
        for i in range(5):
            sfx = rng.integers(0, 64, size=(int(rng.integers(10, 21)),))
            prompts[i] = np.concatenate([template, sfx]).astype(np.int32)
            reqs.append(Request(i, len(prompts[i]), 5))
        ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                            keep_recent=16,
                                            refresh_every=12)
        base = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                         kv_compress=ccfg, prefill_chunk=8,
                                         paged=self.PG), params)
        ref = {o.uid: o.tokens for o in base.serve(reqs, prompts)}
        srv = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=ccfg, prefill_chunk=8,
                                        paged=self.PG,
                                        prefix_share=PrefixShareConfig()),
                     params)
        outs = srv.serve(reqs, prompts)
        for o in outs:
            assert o.tokens == ref[o.uid], o.uid
        # at least every request after the first shares the 24-token
        # template (3 chunks): the template boundary stays registered
        # even as each stream registers suffix-contaminated boundaries
        st = srv.last_stats
        assert st["prefix_hits"] >= len(reqs) - 2
        assert st["prefix_tokens_reused"] >= 24 * (len(reqs) - 2)
        assert st["pool_blocks_end"] == 0.0

    def test_oversubscribed_burst_defers_instead_of_raising(self, pieces):
        """Regression (PoolExhausted mid-serve used to kill the whole
        batch): an oversubscribed pool + burst completes — admissions
        defer back to the queue and ring writes stall their slot until
        the compaction give-back — with tokens STILL bit-identical to
        the dense engine (stalls delay slots, but per-slot cadence keeps
        every slot's stream a function of its own tokens)."""
        params = pieces[0]
        reqs, prompts = TestPagedEngine._stream()
        ccfg = TestPagedEngine.CCFG
        for chunk in (8, 0):
            dense = Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                              kv_compress=ccfg,
                                              prefill_chunk=chunk), params)
            ref = {o.uid: o.tokens for o in dense.serve(reqs, prompts)}
            srv = Server(TINY, ServerConfig(
                batch_size=2, max_seq=96, kv_compress=ccfg,
                prefill_chunk=chunk,
                paged=PagedKVConfig(block_size=4, pool_blocks=7)), params)
            outs = srv.serve(reqs, prompts)       # must not raise
            for o in outs:
                assert o.tokens == ref[o.uid], (chunk, o.uid)
            assert srv.last_stats["pool_blocks_end"] == 0.0

    def test_validation(self, pieces):
        from repro.runtime.prefix_cache import PrefixShareConfig
        params = pieces[0]
        ccfg = TestPagedEngine.CCFG
        with pytest.raises(ValueError, match="prefix_share"):
            Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                      kv_compress=ccfg, prefill_chunk=8,
                                      prefix_share=PrefixShareConfig()),
                   params)
        with pytest.raises(ValueError, match="prefix_share"):
            Server(TINY, ServerConfig(batch_size=2, max_seq=96,
                                      kv_compress=ccfg, paged=self.PG,
                                      prefix_share=PrefixShareConfig()),
                   params)


class TestBatchedCompress:
    def test_matches_per_head_loop(self):
        rng = np.random.default_rng(1)
        B, S, H, Dh = 2, 96, 2, 16
        k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        lengths = jnp.asarray([96, 80], jnp.int32)
        cfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                           keep_recent=16, refresh_every=8)
        cc = kv_compress.compress_cache_batched(k, v, lengths, cfg)
        np.testing.assert_array_equal(np.asarray(cc["cov"]), [88, 72])
        for b in range(B):
            cov_b = int(np.asarray(cc["cov"])[b])
            w_b = (jnp.arange(S) < cov_b).astype(jnp.float32)
            for h in range(H):
                kc, vc, cnt = kv_compress.compress_head(
                    k[b, :, h], v[b, :, h], cfg, weights=w_b)
                np.testing.assert_allclose(
                    np.asarray(cc["k_cents"][b, :, h]), np.asarray(kc),
                    rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(cc["v_cents"][b, :, h]), np.asarray(vc),
                    rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(cc["counts"][b, :, h]), np.asarray(cnt),
                    rtol=1e-4, atol=1e-4)

    def test_tail_ring_layout(self):
        rng = np.random.default_rng(2)
        S, H, Dh = 64, 1, 8
        k = jnp.asarray(rng.normal(size=(1, S, H, Dh)), jnp.float32)
        cfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                           keep_recent=8, refresh_every=4)
        cc = kv_compress.compress_cache_batched(
            k, k, jnp.asarray([50]), cfg)
        # position p lives at ring slot p % R: check position 47 (slot 7)
        np.testing.assert_allclose(np.asarray(cc["k_tail"][0, 47 % 8, 0]),
                                   np.asarray(k[0, 47, 0]), rtol=1e-6)

    def test_recompact_conserves_and_advances(self):
        rng = np.random.default_rng(3)
        B, S, H, Dh = 2, 96, 2, 16
        k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
        lengths = jnp.asarray([96, 80], jnp.int32)
        cfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                           keep_recent=16, refresh_every=8)
        cc = kv_compress.compress_cache_batched(k, v, lengths, cfg)
        cc2 = kv_compress.recompact_clustered(cc, lengths + 8, cfg)
        cov1, cov2 = np.asarray(cc["cov"]), np.asarray(cc2["cov"])
        assert (cov2 >= cov1).all()
        # total summarized mass == number of covered positions, per slot
        m1 = np.asarray(cc["counts"]).sum(axis=(1, 2))
        m2 = np.asarray(cc2["counts"]).sum(axis=(1, 2))
        h = np.asarray(cc["counts"]).shape[2]
        np.testing.assert_allclose(m1, cov1 * h, rtol=1e-5)
        np.testing.assert_allclose(m2, cov2 * h, rtol=1e-5)


# ---------------------------------------------------------------------------
# Recurrent-state families: mamba2-style ('M') and RG-LRU ('R') layers
# serving through the same chunked/paged continuous engine.  The exit pin
# for the layer-state refactor: greedy tokens bit-identical to a blocking
# one-request-at-a-time static decode, because (a) sequential recurrent
# prefill replays exactly the decode step and (b) per-slot recurrent
# state is advanced/checkpointed with slot-local math only.
# ---------------------------------------------------------------------------

from repro.models.config import SSMConfig  # noqa: E402

GM_REC = ModelConfig(name="gm", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="GM",
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                   head_dim=32, n_groups=1, chunk=32))
GR_REC = ModelConfig(name="gr", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="GR", lru_width=64)
M_PURE = ModelConfig(name="m", family="ssm", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=64, pad_vocab_multiple=16, dtype="float32",
                     layer_pattern="M",
                     ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                   head_dim=32, n_groups=1, chunk=32))


def _rec_stream(vocab=64, seed=9):
    rng = np.random.default_rng(seed)
    reqs = [Request(i, int(l), g) for i, (l, g) in
            enumerate([(60, 12), (9, 10), (48, 9), (21, 14)])]
    prompts = {r.uid: rng.integers(0, vocab, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    return reqs, prompts


@pytest.fixture(scope="module", params=["GM", "GR"], ids=["gm", "gr"])
def rec_pieces(request):
    cfg = {"GM": GM_REC, "GR": GR_REC}[request.param]
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    reqs, prompts = _rec_stream()
    ref = Server(cfg, ServerConfig(batch_size=1, max_seq=96,
                                   engine="static",
                                   use_clustered_batching=False), params)
    ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
    return cfg, params, reqs, prompts, ref_out


class TestRecurrentServing:
    CCFG = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)

    def test_chunked_dense_bit_identical(self, rec_pieces):
        cfg, params, reqs, prompts, ref_out = rec_pieces
        srv = Server(cfg, ServerConfig(batch_size=2, max_seq=96,
                                       kv_compress=self.CCFG,
                                       prefill_chunk=8), params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], f"uid {o.uid} diverged"

    def test_chunked_paged_bit_identical(self, rec_pieces):
        cfg, params, reqs, prompts, ref_out = rec_pieces
        srv = Server(cfg, ServerConfig(batch_size=2, max_seq=96,
                                       kv_compress=self.CCFG,
                                       prefill_chunk=8,
                                       paged=PagedKVConfig(block_size=4)),
                     params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], f"uid {o.uid} diverged"
        st = srv.last_stats
        # both families are priced and visible in the metrics surface
        assert st["state_bytes_recurrent"] > 0
        assert st["state_bytes_ring"] > 0
        # recurrent state never retires — the counter exists and stays 0
        assert st["kv_retired_recurrent"] == 0
        assert st["pool_blocks_end"] == 0

    def test_preempt_swap_resume_bit_identical(self, rec_pieces):
        """One preempt→host-swap→resume cycle through recurrent state:
        the snapshot carries the (conv, ssm)/(conv, h) leaves whole, the
        swap-bytes ledger prices them, and restored requests finish with
        exactly the tokens of an unpressured run."""
        from repro.runtime.scheduler import SLOConfig
        cfg, params, reqs, prompts, ref_out = rec_pieces
        rng = np.random.default_rng(3)
        reqs, prompts = [], {}
        for i in range(8):
            plen = int(rng.integers(6, 30))
            prompts[i] = rng.integers(0, 64, size=(plen,)).astype(np.int32)
            reqs.append(Request(i, plen, int(rng.integers(6, 14)),
                                priority=1 if i >= 5 else 0))
        big = Server(cfg, ServerConfig(
            batch_size=2, max_seq=96, kv_compress=self.CCFG,
            prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=48),
            use_clustered_batching=False), params)
        want = {o.uid: o.tokens for o in big.serve(reqs, prompts)}
        tight = Server(cfg, ServerConfig(
            batch_size=2, max_seq=96, kv_compress=self.CCFG,
            prefill_chunk=8,
            paged=PagedKVConfig(block_size=4, pool_blocks=10),
            use_clustered_batching=False,
            # arrival-order admission: the late high-priority tail can
            # only run by preempting a resident best-effort request
            scheduler=SLOConfig(priority_admission=False)), params)
        outs = tight.serve(reqs, prompts)
        st = tight.last_stats
        assert st["sched_preemptions"] >= 1
        assert st["sched_swaps_in"] >= 1
        assert st["sched_swap_bytes"] == 0  # ledger drains to zero
        shed = {o.uid for o in outs if o.shed}
        for o in outs:
            if o.uid not in shed:
                assert o.tokens == want[o.uid], f"uid {o.uid} diverged"

    def test_pure_recurrent_dense_chunked(self):
        """An attention-free pattern (no ring layers at all) still
        serves chunked dense — the engine no longer assumes a KV ring
        exists anywhere."""
        params = tfm.init_params(jax.random.PRNGKey(0), M_PURE)
        reqs, prompts = _rec_stream()
        ref = Server(M_PURE, ServerConfig(batch_size=1, max_seq=96,
                                          engine="static",
                                          use_clustered_batching=False),
                     params)
        ref_out = {o.uid: o.tokens for o in ref.serve(reqs, prompts)}
        srv = Server(M_PURE, ServerConfig(batch_size=2, max_seq=96,
                                          prefill_chunk=8), params)
        for o in srv.serve(reqs, prompts):
            assert o.tokens == ref_out[o.uid], f"uid {o.uid} diverged"

    def test_pure_recurrent_paged_rejected(self):
        """Recurrent state is never pool-backed, so a pure-recurrent
        pattern has nothing to page — the gate must say so."""
        params = tfm.init_params(jax.random.PRNGKey(0), M_PURE)
        with pytest.raises(ValueError, match="ring-family"):
            Server(M_PURE, ServerConfig(batch_size=2, max_seq=96,
                                        kv_compress=self.CCFG,
                                        prefill_chunk=8,
                                        paged=PagedKVConfig(block_size=4)),
                   params)
