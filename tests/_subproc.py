"""Shared subprocess harness for multi-device tests.

The main test process must keep the default single device (dry-run
contract), so every shard_map / mesh test runs its payload in a child
process with XLA_FLAGS forcing 8 host devices.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
