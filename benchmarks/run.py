"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  t1_median_throughput     paper's core claim: bit-serial median vs sort
                           baseline (wall time) + data-movement model ratio
                           (in-situ: 1 HBM pass; processor: B passes)
  t2_recognition_rate      paper Table 3: recognition rate vs #clusters
                           on the five UCI-style datasets
  t3_fixed_point           paper §4: quality at 8/16/32/64-bit fixed point
                           vs float64 (64-bit ≈ double claim)
  t4_optimal_k             paper §4 loop: avgBMP(k) sweep finds k*
  t5_kmedians_end2end      full Lloyd k-medians vs k-means wall time +
                           robustness on the outlier table
  kv_compress              clustered-KV attention error vs memory ratio
  request_batching         padding waste: clustered vs FIFO batching
  grad_compress            codebook gradient compression: wire ratio +
                           quantization error
  prefix_share             shared-prefix burst on the paged chunked
                           engine: every request = one long template +
                           a short unique suffix; with prefix sharing
                           on, admissions adopt the template's tail
                           blocks + centroids (copy-on-write) instead
                           of re-prefilling — p95 TTFT and physical
                           peak-KV must drop at identical tokens
  template_store           repeat-serve templated traffic on the
                           persistent cross-serve template store: the
                           same server serves two bursts sharing a
                           template; the second (warm) serve must beat
                           the first on p95 TTFT with warm prefix hits
                           > 0 and greedy tokens bit-identical to a
                           cold-store serve of the same stream, and the
                           store's traffic clusters (cohesion, hit
                           rate, bytes pinned) are recorded
  serve                    end-to-end serving engine: tokens/s + padded-
                           token waste for FIFO vs clustered batching,
                           static vs continuous, and continuous with
                           clustered-KV compaction (fused Pallas
                           clustered_decode path, interpret mode on CPU).
                           ``--mesh DATAxMODEL`` adds mesh-sharded
                           variants (slots over data, heads over model)
                           so 1x1 vs NxM tokens/s compare directly;
                           ``--paged`` adds the paged memory manager
                           (block-pool KV tails, packed ragged launches)
                           and records its padded-compute waste vs the
                           dense bucketed path; ``--seed`` + the JSON
                           record at --json-out (deduplicated on git sha
                           + seed + mesh + scenario, with the Pallas
                           backend/interpret flag stamped per run) make
                           FIFO-vs-clustered runs reproducible
  roofline_summary         headline numbers from the dry-run artifacts

Run: ``PYTHONPATH=src python -m benchmarks.run [--quick] [scenario]``
e.g. ``python -m benchmarks.run serve --mesh 2x4 --seed 7``
"""

from __future__ import annotations

import os
import sys

from repro.launch.preboot import force_host_devices_for_mesh

force_host_devices_for_mesh(sys.argv)

import argparse  # noqa: E402
import json  # noqa: E402
import glob  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bitserial, clustering, grad_compress, kv_compress  # noqa: E402
from repro.core.clustering import ClusterConfig  # noqa: E402
from repro.core.request_cluster import Request, plan_batches, plan_fifo  # noqa: E402
from repro.data import pipeline  # noqa: E402


def _time(fn, n=5) -> float:
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------


def t1_median_throughput(quick=False):
    rng = np.random.default_rng(0)
    n, d = (4096, 64) if quick else (16384, 128)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    bits = 32
    f_bs = jax.jit(lambda v: bitserial.median(v, bits=bits))
    f_sort = jax.jit(lambda v: bitserial.sort_median_ref(v, axis=0))
    us_bs = _time(lambda: f_bs(x))
    us_sort = _time(lambda: f_sort(x))
    # data-movement model: processor baseline re-reads the array per bit;
    # the in-situ kernel reads it once (VMEM-resident scan)
    movement_ratio = bits  # B passes vs 1
    emit("t1_median_bitserial", us_bs,
         f"sort_us={us_sort:.1f};speedup_vs_sort={us_sort / us_bs:.2f}x;"
         f"in_situ_traffic_reduction={movement_ratio}x_model")


def t2_recognition_rate(quick=False):
    suite = pipeline.uci_style_suite(seed=0)
    ks = [3, 5, 10, 14, 16]
    for name, (x, y) in suite.items():
        xs = jnp.asarray((x - x.mean(0)) / (x.std(0) + 1e-6))
        n_classes = int(y.max()) + 1
        rates = []
        t0 = time.perf_counter()
        for k in ks:
            cfg = ClusterConfig(k=k, centroid="median", metric="l1",
                                seed=1, max_iters=25)
            res = clustering.fit(xs, cfg, use_kernel=False)
            r = clustering.recognition_rate(res.assign, jnp.asarray(y), k,
                                            n_classes)
            rates.append(round(float(r) * 100, 2))
        us = (time.perf_counter() - t0) / len(ks) * 1e6
        emit(f"t2_recognition_{name}", us,
             ";".join(f"k{k}={r}" for k, r in zip(ks, rates)))


def t3_fixed_point(quick=False):
    x, y = pipeline.wine_like(n=1000 if quick else 4595, seed=0)
    xs = (x - x.mean(0)) / (x.std(0) + 1e-6)
    from repro.kernels.ref import lower_median_ref
    ref64 = lower_median_ref(np.asarray(xs, np.float64), axis=0)
    for bits in (8, 16, 32):
        t0 = time.perf_counter()
        med = bitserial.median(jnp.asarray(xs), bits=bits)
        med.block_until_ready()
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(med, np.float64) - ref64)))
        emit(f"t3_fixed_point_b{bits}", us, f"max_err_vs_double={err:.2e}")
    # 64-bit two-limb path (host encode, paper's '64-bit ≈ double')
    from repro.core import quantizer
    scale = 2.0**40
    hi, lo = quantizer.quantize64_host(np.asarray(xs, np.float64), scale)
    t0 = time.perf_counter()
    mh, ml = bitserial.median_bits64(jnp.asarray(hi), jnp.asarray(lo))
    mh.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    got = quantizer.dequantize64_host(np.asarray(mh), np.asarray(ml), scale)
    err = float(np.max(np.abs(got - ref64)))
    emit("t3_fixed_point_b64", us, f"max_err_vs_double={err:.2e}")


def t4_optimal_k(quick=False):
    centers = np.array([[0, 0], [6, 6], [-6, 6], [6, -6]], np.float32)
    x, _ = pipeline.gaussian_blobs(80, centers, std=0.4, seed=3)
    t0 = time.perf_counter()
    k_opt, scores = clustering.select_k(
        jnp.asarray(x), 2, 6, ClusterConfig(k=2, centroid="mean",
                                            metric="l2"))
    us = (time.perf_counter() - t0) * 1e6
    emit("t4_optimal_k", us,
         f"k_opt={k_opt};true_k=4;scores="
         + "|".join(f"{s:.3f}" for s in scores))


def t5_kmedians_end2end(quick=False):
    x, y = pipeline.census_like(n=2000 if quick else 5000, seed=2,
                                outlier_frac=0.02)
    xs = jnp.asarray(x)
    cfg_med = ClusterConfig(k=5, centroid="median", metric="l1", seed=3,
                            max_iters=20)
    cfg_mean = ClusterConfig(k=5, centroid="mean", metric="l2", seed=3,
                             max_iters=20)
    f_med = jax.jit(lambda v: clustering.fit(v, cfg_med,
                                             use_kernel=False).centroids)
    f_mean = jax.jit(lambda v: clustering.fit(v, cfg_mean,
                                              use_kernel=False).centroids)
    us_med = _time(lambda: f_med(xs), n=3)
    us_mean = _time(lambda: f_mean(xs), n=3)
    res_med = clustering.fit(xs, cfg_med, use_kernel=False)
    res_mean = clustering.fit(xs, cfg_mean, use_kernel=False)
    r_med = float(clustering.recognition_rate(res_med.assign,
                                              jnp.asarray(y), 5, 5))
    r_mean = float(clustering.recognition_rate(res_mean.assign,
                                               jnp.asarray(y), 5, 5))
    emit("t5_kmedians_end2end", us_med,
         f"kmeans_us={us_mean:.1f};recog_median={r_med:.3f};"
         f"recog_mean={r_mean:.3f}")


def kv_compress_bench(quick=False):
    rng = np.random.default_rng(1)
    s, h, dh = (1024, 4, 64) if quick else (4096, 8, 64)
    centers = rng.normal(size=(32, dh)) * 2
    k = np.stack([(centers[rng.integers(0, 32, size=s)]
                   + rng.normal(size=(s, dh)) * 0.15) for _ in range(h)], 1)
    v = rng.normal(size=(s, h, dh))
    q = rng.normal(size=(h, dh)).astype(np.float32)
    kj = jnp.asarray(k, jnp.float32)
    vj = jnp.asarray(v, jnp.float32)
    qj = jnp.asarray(q)
    for c in (64, 256):
        cfg = kv_compress.KVCompressConfig(n_clusters=c, iters=6,
                                           keep_recent=128)
        t0 = time.perf_counter()
        ckv = kv_compress.compress_cache(kj, vj, cfg)
        jax.block_until_ready(ckv.k_cents)
        us = (time.perf_counter() - t0) * 1e6
        out_c = kv_compress.clustered_attention(qj, ckv, scale=dh**-0.5)
        out_e = kv_compress.exact_attention(qj, kj, vj, scale=dh**-0.5)
        err = float(jnp.linalg.norm(out_c - out_e)
                    / jnp.linalg.norm(out_e))
        emit(f"kv_compress_c{c}", us,
             f"mem_ratio={kv_compress.memory_ratio(s, cfg):.1f}x;"
             f"rel_err={err:.4f}")


def request_batching_bench(quick=False):
    rng = np.random.default_rng(4)
    n = 128 if quick else 512
    lens = np.where(rng.random(n) < 0.6,
                    rng.integers(16, 64, n), rng.integers(512, 2048, n))
    reqs = [Request(i, int(l), 16) for i, l in enumerate(lens)]
    t0 = time.perf_counter()
    plan_c = plan_batches(reqs, batch_size=16)
    us = (time.perf_counter() - t0) * 1e6
    plan_f = plan_fifo(reqs, batch_size=16)
    emit("request_batching", us,
         f"clustered_waste={plan_c.waste:.4f};fifo_waste={plan_f.waste:.4f};"
         f"waste_reduction={plan_f.waste / max(plan_c.waste, 1e-9):.1f}x")


def grad_compress_bench(quick=False):
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.normal(size=(512, 1024)).astype(np.float32))
    cfg = grad_compress.CompressConfig(k=16, iters=8)
    f = jax.jit(lambda v: grad_compress.compress_decompress(v, cfg)[0])
    us = _time(lambda: f(g), n=3)
    g_hat, err = grad_compress.compress_decompress(g, cfg)
    rel = float(jnp.linalg.norm(err) / jnp.linalg.norm(g))
    wire = grad_compress.wire_bytes({"g": g}, cfg)
    emit("grad_compress", us,
         f"wire_ratio={wire['ratio']:.1f}x;rel_err={rel:.4f}")


def _git_sha() -> str:
    import subprocess
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def _append_serve_json(json_out, run_key, payload) -> int:
    """Append one serve-bench run record, deduplicated on (git sha, seed,
    mesh, scenario) — re-runs of the same commit/config replace their
    record instead of stacking duplicates.  Legacy records (pre-scenario)
    are rekeyed from their quick flag.  Returns the history length."""
    def _key_of(h):
        sc = h.get("scenario")
        if sc is None:          # legacy record: quick flag only
            sc = "serve" + ("_quick" if h.get("quick") else "")
        return {"git_sha": h.get("git_sha"), "seed": h.get("seed"),
                "mesh": h.get("mesh"), "scenario": sc}

    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    history = []
    if os.path.exists(json_out):
        try:
            with open(json_out) as fh:
                history = json.load(fh)
            if not isinstance(history, list):
                history = []
        except (json.JSONDecodeError, OSError):
            history = []
    history = [h for h in history
               if isinstance(h, dict) and "records" in h  # old format
               and _key_of(h) != run_key]
    history.append({**run_key, **payload})
    with open(json_out, "w") as fh:
        json.dump(history, fh, indent=1)
    return len(history)


def serve_bench(quick=False, seed=7, mesh_spec=None,
                json_out="artifacts/serve_bench.json", paged=False):
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.server import Server, ServerConfig

    SMALL = ModelConfig(name="serve-lm", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                        d_ff=256, vocab=256, pad_vocab_multiple=128,
                        dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), SMALL)
    # --seed drives the whole request stream (lengths, budgets, prompts),
    # so FIFO-vs-clustered comparisons replay the exact same queue.
    # Bursty admission: every request is queued at t0 with a bimodal
    # prompt-length mix, so slots churn and admission pressure stays high
    # for the whole run — the regime where blocking prefill stalls decode.
    rng = np.random.default_rng(seed)
    n = 12 if quick else 32
    lens = np.where(rng.random(n) < 0.5,
                    rng.integers(8, 24, n), rng.integers(72, 120, n))
    reqs = [Request(i, int(l), int(rng.integers(4, 9)))
            for i, l in enumerate(lens)]
    prompts = {r.uid: rng.integers(0, 256, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    ccfg = kv_compress.KVCompressConfig(n_clusters=16, iters=4,
                                        keep_recent=32, refresh_every=16)
    chunk = 16
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None
    variants = [
        ("serve_static_fifo", ServerConfig(
            batch_size=4, max_seq=256, engine="static",
            use_clustered_batching=False)),
        ("serve_static_clustered", ServerConfig(
            batch_size=4, max_seq=256, engine="static")),
        ("serve_cont_fifo", ServerConfig(
            batch_size=4, max_seq=256, use_clustered_batching=False)),
        ("serve_cont_fifo_chunked", ServerConfig(
            batch_size=4, max_seq=256, use_clustered_batching=False,
            prefill_chunk=chunk)),
        ("serve_cont_clustered", ServerConfig(batch_size=4, max_seq=256)),
        ("serve_cont_clustered_chunked", ServerConfig(
            batch_size=4, max_seq=256, prefill_chunk=chunk)),
        ("serve_cont_clustered_compact", ServerConfig(
            batch_size=4, max_seq=256, kv_compress=ccfg)),
        ("serve_cont_clustered_compact_chunked", ServerConfig(
            batch_size=4, max_seq=256, kv_compress=ccfg,
            prefill_chunk=chunk)),
    ]
    if paged:
        # paged memory manager (block-pool tails + packed ragged
        # launches): same queue, same ccfg — tokens must stay identical
        # to the dense clustered engine while padded-launch compute and
        # peak KV bytes drop
        pcfg = PagedKVConfig(block_size=8)
        variants += [
            ("serve_cont_paged_compact", ServerConfig(
                batch_size=4, max_seq=256, kv_compress=ccfg, paged=pcfg)),
            ("serve_cont_paged_compact_chunked", ServerConfig(
                batch_size=4, max_seq=256, kv_compress=ccfg,
                prefill_chunk=chunk, paged=pcfg)),
        ]
    if mesh is not None:
        # mesh dimension of the scenario: same queue, same batch_size,
        # sharded engine — tokens/s compares 1x1 (variants above) vs
        # data x model directly (slot sharding needs batch_size % data
        # == 0; otherwise slots replicate and only heads shard)
        tag = mesh_spec.lower()
        variants += [
            (f"serve_cont_clustered_mesh{tag}", ServerConfig(
                batch_size=4, max_seq=256, mesh=mesh)),
            (f"serve_cont_clustered_chunked_mesh{tag}", ServerConfig(
                batch_size=4, max_seq=256, prefill_chunk=chunk, mesh=mesh)),
            (f"serve_cont_clustered_compact_mesh{tag}", ServerConfig(
                batch_size=4, max_seq=256, kv_compress=ccfg, mesh=mesh)),
            (f"serve_cont_clustered_compact_chunked_mesh{tag}", ServerConfig(
                batch_size=4, max_seq=256, kv_compress=ccfg,
                prefill_chunk=chunk, mesh=mesh)),
        ]
        if paged:
            variants += [
                (f"serve_cont_paged_compact_chunked_mesh{tag}", ServerConfig(
                    batch_size=4, max_seq=256, kv_compress=ccfg,
                    prefill_chunk=chunk, paged=PagedKVConfig(block_size=8),
                    mesh=mesh)),
            ]
    # the probe stream stands for the server's pre-burst traffic: a short-
    # prompt trickle that warms the decode path but NOT the long-prompt
    # admission shapes — so the timed burst charges each engine for the
    # admission machinery it actually exercises when heavy mixed traffic
    # arrives (blocking: a prefill trace per novel bucket length + a
    # decode stall per admission; chunked: two fixed launch shapes)
    # staggered budgets walk the probe's drain through every launch-bucket
    # shape, the way any long-lived server will have before a burst lands
    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(8, 3), (10, 5), (12, 9), (9, 18)])]
    probe_prompts = {r.uid: rng.integers(0, 256, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    records = []
    tokens_by_variant = {}
    for name, scfg in variants:
        srv = Server(SMALL, scfg, params)
        srv.serve(probe, probe_prompts)
        # timed bursty-admission pass: every request lands at t0 on the
        # warmed-for-short-traffic server
        t0 = time.perf_counter()
        outs = srv.serve(reqs, prompts)
        wall = time.perf_counter() - t0
        burst_stats = dict(srv.last_stats)
        # steady-state pass: same stream again, every shape warm
        t0 = time.perf_counter()
        srv.serve(reqs, prompts)
        wall_steady = time.perf_counter() - t0
        steady = {f"steady_{k}": float(v) for k, v in srv.last_stats.items()
                  if k in ("tokens_per_s_wall", "ttft_p95_ms", "itl_p95_ms")}
        toks = sum(len(o.tokens) for o in outs)
        tokens_by_variant[name] = {o.uid: o.tokens for o in outs}
        if scfg.engine == "static":
            waste = burst_stats.get("plan_waste", 0.0)
            derived = (f"tokens_per_s={toks / wall:.1f};"
                       f"prompt_pad_waste={waste:.4f}")
            rec_stats = {"tokens_per_s_wall": toks / wall,
                         "prompt_pad_waste": waste}
            steady = {"steady_tokens_per_s_wall": toks / max(wall_steady,
                                                             1e-9)}
        else:
            rec_stats = {k: float(v) for k, v in burst_stats.items()}
            derived = (f"tokens_per_s_wall={rec_stats['tokens_per_s_wall']:.1f};"
                       f"ttft_p95_ms={rec_stats['ttft_p95_ms']:.1f};"
                       f"itl_p95_ms={rec_stats['itl_p95_ms']:.1f};"
                       f"slot_waste={rec_stats['slot_waste']:.4f};"
                       f"launch_rows_frac={rec_stats['launch_rows_frac']:.4f}")
        emit(name, wall * 1e6, derived)
        records.append({
            "name": name, "seed": seed,
            "mesh": mesh_spec if scfg.mesh is not None else "1x1",
            "batch_size": scfg.batch_size, "requests": n,
            "wall_s": wall, "wall_s_steady": wall_steady,
            "gen_tokens": toks, **rec_stats, **steady,
        })

    # acceptance: chunked admission must beat blocking on wall tokens/s
    # AND p95 TTFT at equal batch size, with identical greedy outputs on
    # the exact-KV engine (same math, different schedule)
    by_name = {r["name"]: r for r in records}
    comparisons = {}
    for blocking, chunked in [
            ("serve_cont_clustered", "serve_cont_clustered_chunked"),
            ("serve_cont_clustered_compact",
             "serve_cont_clustered_compact_chunked")]:
        if blocking not in by_name or chunked not in by_name:
            continue
        rb, rc = by_name[blocking], by_name[chunked]
        same = tokens_by_variant[blocking] == tokens_by_variant[chunked]
        cmp = {
            "tokens_per_s_wall_blocking": rb["tokens_per_s_wall"],
            "tokens_per_s_wall_chunked": rc["tokens_per_s_wall"],
            "speedup": rc["tokens_per_s_wall"]
            / max(rb["tokens_per_s_wall"], 1e-9),
            "ttft_p95_ms_blocking": rb["ttft_p95_ms"],
            "ttft_p95_ms_chunked": rc["ttft_p95_ms"],
            "ttft_p95_ratio": rc["ttft_p95_ms"]
            / max(rb["ttft_p95_ms"], 1e-9),
            "tokens_identical": bool(same),
        }
        comparisons[chunked] = cmp
        emit(f"{chunked}_vs_blocking", 0.0,
             f"speedup={cmp['speedup']:.2f}x;"
             f"ttft_p95_ratio={cmp['ttft_p95_ratio']:.2f};"
             f"tokens_identical={same}")

    # paged vs dense on the same bursty queue: packed ragged launches must
    # make padded-launch compute strictly smaller than the dense bucketed
    # path while greedy tokens stay identical
    for dense_name, paged_name in [
            ("serve_cont_clustered_compact", "serve_cont_paged_compact"),
            ("serve_cont_clustered_compact_chunked",
             "serve_cont_paged_compact_chunked")]:
        if dense_name not in by_name or paged_name not in by_name:
            continue
        rd, rp = by_name[dense_name], by_name[paged_name]
        same = tokens_by_variant[dense_name] == tokens_by_variant[paged_name]
        cmp = {
            "launch_pad_frac_dense": rd["launch_pad_frac"],
            "launch_pad_frac_paged": rp["launch_pad_frac"],
            "pad_waste_below_dense": bool(
                rp["launch_pad_frac"] < rd["launch_pad_frac"]),
            "kv_bytes_peak_per_shard_dense": rd["kv_bytes_peak_per_shard"],
            "kv_bytes_peak_per_shard_paged": rp["kv_bytes_peak_per_shard"],
            "tokens_identical": bool(same),
        }
        comparisons[paged_name] = cmp
        emit(f"{paged_name}_vs_dense", 0.0,
             f"pad_frac={rp['launch_pad_frac']:.3f}_vs_"
             f"{rd['launch_pad_frac']:.3f};"
             f"below_dense={cmp['pad_waste_below_dense']};"
             f"kv_bytes_ratio={rp['kv_bytes_peak_per_shard'] / max(rd['kv_bytes_peak_per_shard'], 1e-9):.2f};"
             f"tokens_identical={same}")

    if json_out:
        scenario = ("serve" + ("_paged" if paged else "")
                    + ("_quick" if quick else ""))
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            # which Pallas backend produced these numbers —
            # interpret-mode CPU results are not comparable
            # to Mosaic-compiled TPU runs
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def prefix_share_bench(quick=False, seed=7, mesh_spec=None,
                       json_out="artifacts/serve_bench.json"):
    """Shared-prefix burst: the templated-traffic regime prefix sharing
    exists for — every request is the same long template plus a short
    unique suffix, all queued at t0.  Serves the burst on the paged
    chunked engine with and without ``prefix_share`` and records p95
    TTFT, physical peak KV bytes, and the sharing counters
    (kv_bytes_saved, prefix_hits); greedy tokens must be identical —
    sharing only skips recomputing state the unshared run derives from
    the same prefix tokens."""
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.prefix_cache import PrefixShareConfig
    from repro.runtime.server import Server, ServerConfig

    SMALL = ModelConfig(name="serve-lm", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                        d_ff=256, vocab=256, pad_vocab_multiple=128,
                        dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), SMALL)
    rng = np.random.default_rng(seed)
    n = 8 if quick else 16
    # template ≫ suffix and refresh < keep_recent: the live ring window
    # at admission is mostly template positions, so later admissions
    # adopt those blocks instead of materializing their own — that is
    # where the physical peak-KV drop comes from (TTFT drops from the
    # skipped template chunks either way)
    template = rng.integers(0, 256, size=(64,)).astype(np.int32)
    reqs, prompts = [], {}
    for i in range(n):
        sfx = rng.integers(0, 256, size=(int(rng.integers(2, 7)),))
        prompts[i] = np.concatenate([template, sfx]).astype(np.int32)
        reqs.append(Request(i, len(prompts[i]), int(rng.integers(3, 6))))
    ccfg = kv_compress.KVCompressConfig(n_clusters=16, iters=4,
                                        keep_recent=32, refresh_every=12)
    chunk, pcfg = 16, PagedKVConfig(block_size=4)
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None

    def scfg(share, use_mesh):
        # max_entries=1: single-template traffic only ever hits one
        # boundary (the pure template), and a tight cap keeps the
        # cache's pinned blocks from inflating the physical peak the
        # scenario is measuring
        return ServerConfig(
            batch_size=4, max_seq=256, kv_compress=ccfg,
            prefill_chunk=chunk, paged=pcfg,
            prefix_share=(PrefixShareConfig(max_entries=1)
                          if share else None),
            mesh=mesh if use_mesh else None)

    variants = [("serve_prefix_unshared", scfg(False, False)),
                ("serve_prefix_shared", scfg(True, False))]
    if mesh is not None:
        tag = mesh_spec.lower()
        variants += [(f"serve_prefix_unshared_mesh{tag}", scfg(False, True)),
                     (f"serve_prefix_shared_mesh{tag}", scfg(True, True))]
    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(9, 3), (11, 5)])]
    probe_prompts = {r.uid: rng.integers(0, 256, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    records, tokens_by_variant = [], {}
    for name, cfg in variants:
        srv = Server(SMALL, cfg, params)
        srv.serve(probe, probe_prompts)       # warm the launch shapes
        t0 = time.perf_counter()
        outs = srv.serve(reqs, prompts)
        wall = time.perf_counter() - t0
        st = {k: float(v) for k, v in srv.last_stats.items()}
        tokens_by_variant[name] = {o.uid: o.tokens for o in outs}
        emit(name, wall * 1e6,
             f"ttft_p95_ms={st['ttft_p95_ms']:.1f};"
             f"kv_bytes_peak_per_shard={st['kv_bytes_peak_per_shard']:.0f};"
             f"prefix_hits={st.get('prefix_hits', 0.0):.0f};"
             f"kv_bytes_saved={st.get('kv_bytes_saved', 0.0):.0f}")
        records.append({
            "name": name, "seed": seed,
            "mesh": mesh_spec if cfg.mesh is not None else "1x1",
            "batch_size": cfg.batch_size, "requests": n,
            "wall_s": wall,
            "gen_tokens": sum(len(o.tokens) for o in outs), **st,
        })

    by_name = {r["name"]: r for r in records}
    comparisons = {}
    for off, on in [("serve_prefix_unshared", "serve_prefix_shared"),
                    (f"serve_prefix_unshared_mesh{(mesh_spec or '').lower()}",
                     f"serve_prefix_shared_mesh{(mesh_spec or '').lower()}")]:
        if off not in by_name or on not in by_name:
            continue
        ro, rs = by_name[off], by_name[on]
        same = tokens_by_variant[off] == tokens_by_variant[on]
        cmp = {
            "ttft_p95_ms_unshared": ro["ttft_p95_ms"],
            "ttft_p95_ms_shared": rs["ttft_p95_ms"],
            "ttft_p95_ratio": rs["ttft_p95_ms"]
            / max(ro["ttft_p95_ms"], 1e-9),
            "kv_bytes_peak_unshared": ro["kv_bytes_peak_per_shard"],
            "kv_bytes_peak_shared": rs["kv_bytes_peak_per_shard"],
            "kv_bytes_peak_below_unshared": bool(
                rs["kv_bytes_peak_per_shard"]
                <= ro["kv_bytes_peak_per_shard"]),
            "kv_bytes_saved": rs.get("kv_bytes_saved", 0.0),
            "prefix_hits": rs.get("prefix_hits", 0.0),
            "tokens_identical": bool(same),
        }
        comparisons[on] = cmp
        emit(f"{on}_vs_unshared", 0.0,
             f"ttft_p95_ratio={cmp['ttft_p95_ratio']:.2f};"
             f"kv_bytes_ratio={rs['kv_bytes_peak_per_shard'] / max(ro['kv_bytes_peak_per_shard'], 1e-9):.2f};"
             f"kv_bytes_saved={cmp['kv_bytes_saved']:.0f};"
             f"tokens_identical={same}")

    if json_out:
        scenario = "serve_prefix" + ("_quick" if quick else "")
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_prefix_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def template_store_bench(quick=False, seed=7, mesh_spec=None,
                         json_out="artifacts/serve_bench.json",
                         trace_out=None):
    """Repeat-serve templated traffic on the persistent template store
    (runtime/template_store.py): one server, two bursts sharing a
    template but with fresh suffixes.  Serve #1 fills the store (and
    still shares within the burst); serve #2 starts warm — every
    admission adopts the template boundary registered by serve #1
    instead of re-prefilling it, so its p95 TTFT must come in below
    serve #1's.  A cold-store server serves burst #2 for the
    bit-identity reference (persistence only skips recomputation, never
    changes tokens).  Store traffic-cluster stats (cohesion, hit rate,
    bytes pinned) ride along in the records.  The store server runs with
    lifecycle tracing ON while the cold reference stays untraced, so the
    tokens_identical check doubles as the tracing-is-schedule-invisible
    acceptance; ``trace_out`` writes its Chrome trace (Perfetto-loadable)
    there."""
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.server import Server, ServerConfig
    from repro.runtime.telemetry import TelemetryConfig, phase_breakdown
    from repro.runtime.template_store import TemplateStoreConfig

    SMALL = ModelConfig(name="serve-lm", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                        d_ff=256, vocab=256, pad_vocab_multiple=128,
                        dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), SMALL)
    rng = np.random.default_rng(seed)
    n = 6 if quick else 12
    template = rng.integers(0, 256, size=(64,)).astype(np.int32)

    def stream(sfx_seed):
        sfx_rng = np.random.default_rng(sfx_seed)
        reqs, prompts = [], {}
        for i in range(n):
            sfx = sfx_rng.integers(0, 256,
                                   size=(int(sfx_rng.integers(2, 7)),))
            prompts[i] = np.concatenate([template, sfx]).astype(np.int32)
            reqs.append(Request(i, len(prompts[i]),
                                int(sfx_rng.integers(3, 6))))
        return reqs, prompts

    reqs1, prompts1 = stream(seed + 1)
    reqs2, prompts2 = stream(seed + 2)
    ccfg = kv_compress.KVCompressConfig(n_clusters=16, iters=4,
                                        keep_recent=32, refresh_every=12)
    # pool headroom above full slot provisioning (32 blocks): persistent
    # entries pin their tail blocks BETWEEN serves, and a pool with zero
    # surplus evicts every entry under pressure before the drain —
    # nothing would survive to warm serve #2
    chunk = 16
    pcfg = PagedKVConfig(block_size=4, pool_blocks=48)
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None

    def scfg(store, use_mesh, trace=False):
        # max_entries=2: single-template traffic hits one boundary; a
        # tight cap bounds the standing pinned-block cost (≤ 2 ring
        # windows per shard) well inside the pool's surplus
        return ServerConfig(
            batch_size=4, max_seq=256, kv_compress=ccfg,
            prefill_chunk=chunk, paged=pcfg,
            template_store=(TemplateStoreConfig(max_entries=2)
                            if store else None),
            telemetry=TelemetryConfig(trace=True) if trace else None,
            mesh=mesh if use_mesh else None)

    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(9, 3), (11, 5)])]
    probe_prompts = {r.uid: rng.integers(0, 256, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    records, comparisons = [], {}
    variant_tags = [("", False)]
    if mesh is not None:
        variant_tags.append((f"_mesh{mesh_spec.lower()}", True))
    for tag, use_mesh in variant_tags:
        cold = Server(SMALL, scfg(False, use_mesh), params)
        cold.serve(probe, probe_prompts)      # warm the launch shapes
        t0 = time.perf_counter()
        outs_cold = cold.serve(reqs2, prompts2)
        wall_cold = time.perf_counter() - t0
        st_cold = {k: float(v) for k, v in cold.last_stats.items()}

        srv = Server(SMALL, scfg(True, use_mesh, trace=True), params)
        srv.serve(probe, probe_prompts)
        serves = []
        for reqs, prompts in [(reqs1, prompts1), (reqs2, prompts2)]:
            t0 = time.perf_counter()
            outs = srv.serve(reqs, prompts)
            serves.append((time.perf_counter() - t0,
                           {k: float(v) for k, v in
                            srv.last_stats.items()},
                           {o.uid: o.tokens for o in outs}))
        (wall1, st1, _toks1), (wall2, st2, toks2) = serves
        # phase breakdown + trace export come from the warm serve (#2),
        # the one whose prefix-hit fast path the scenario exists to show
        phase_ms = phase_breakdown(srv.last_trace)
        if trace_out:
            os.makedirs(trace_out, exist_ok=True)
            srv.export_trace(os.path.join(trace_out,
                                          f"trace_template{tag}.json"))

        same = toks2 == {o.uid: o.tokens for o in outs_cold}
        for name, wall, st in [
                (f"serve_tmpl_cold{tag}", wall_cold, st_cold),
                (f"serve_tmpl_store1{tag}", wall1, st1),
                (f"serve_tmpl_store2{tag}", wall2, st2)]:
            emit(name, wall * 1e6,
                 f"ttft_p95_ms={st['ttft_p95_ms']:.1f};"
                 f"prefix_hits={st.get('prefix_hits', 0.0):.0f};"
                 f"template_pinned_blocks="
                 f"{st.get('template_pinned_blocks', 0.0):.0f};"
                 f"cohesion={st.get('template_cohesion_mean', 0.0):.3f}")
            records.append({
                "name": name, "seed": seed,
                "mesh": mesh_spec if use_mesh else "1x1",
                "batch_size": 4, "requests": n, "wall_s": wall, **st,
                **({"phase_ms": phase_ms}
                   if name == f"serve_tmpl_store2{tag}" else {}),
            })
        cmp = {
            "ttft_p95_ms_cold_store": st1["ttft_p95_ms"],
            "ttft_p95_ms_warm": st2["ttft_p95_ms"],
            "ttft_p95_ratio": st2["ttft_p95_ms"]
            / max(st1["ttft_p95_ms"], 1e-9),
            "warm_beats_cold_ttft": bool(
                st2["ttft_p95_ms"] < st1["ttft_p95_ms"]),
            "prefix_hits_warm": st2.get("prefix_hits", 0.0),
            "template_pinned_blocks": st2.get("template_pinned_blocks",
                                              0.0),
            "template_cohesion_mean": st2.get("template_cohesion_mean",
                                              0.0),
            "template_cluster0_hit_rate": st2.get(
                "template_cluster0_hit_rate", 0.0),
            "tokens_identical": bool(same),
        }
        comparisons[f"serve_tmpl_store2{tag}"] = cmp
        emit(f"serve_tmpl_store2{tag}_vs_store1", 0.0,
             f"ttft_p95_ratio={cmp['ttft_p95_ratio']:.2f};"
             f"warm_beats_cold={cmp['warm_beats_cold_ttft']};"
             f"prefix_hits_warm={cmp['prefix_hits_warm']:.0f};"
             f"tokens_identical={same}")

    if json_out:
        scenario = "serve_template" + ("_quick" if quick else "")
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_template_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def window_bench(quick=False, seed=7, mesh_spec=None,
                 json_out="artifacts/serve_bench.json"):
    """Sliding-window serving — the model-zoo door the retention-policy
    layer opens: a gemma2-style reduced config (alternating 'LG'
    local/global layers, softcaps, sandwich norms) served by the chunked
    + paged engine vs blocking dense admission.  'L' layers retire
    behind WindowRetention (dense window rings, per-row wlo kernel
    floors), 'G' layers stay clustered behind FrontierRetention; greedy
    tokens must be identical across the two schedules, and the
    per-policy retirement counters (kv_retired_window /
    kv_retired_frontier) are recorded.  ``--mesh 2x4`` adds the sharded
    pair."""
    import dataclasses as dc

    from repro import configs
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.server import Server, ServerConfig

    GL = dc.replace(configs.get_reduced("gemma2-27b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), GL)
    rng = np.random.default_rng(seed)
    n = 6 if quick else 12
    # prompts fit the clustered tail ring (loss-free admission ⇒ token
    # identity across schedules) but exceed the 16-token window; budgets
    # push past keep_recent so compactions advance the 'G' frontier
    reqs = [Request(i, int(rng.integers(8, 28)), int(rng.integers(4, 11)))
            for i in range(n)]
    prompts = {r.uid: rng.integers(0, GL.vocab, size=(r.prompt_len,))
               .astype(np.int32) for r in reqs}
    ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                        keep_recent=32, refresh_every=4)
    chunk, pcfg = 8, PagedKVConfig(block_size=4)
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None

    def scfg(chunked_paged, use_mesh):
        return ServerConfig(
            batch_size=4, max_seq=96, kv_compress=ccfg,
            prefill_chunk=chunk if chunked_paged else 0,
            paged=pcfg if chunked_paged else None,
            mesh=mesh if use_mesh else None)

    variants = [("serve_window_blocking", scfg(False, False)),
                ("serve_window_paged_chunked", scfg(True, False))]
    if mesh is not None:
        tag = mesh_spec.lower()
        variants += [
            (f"serve_window_blocking_mesh{tag}", scfg(False, True)),
            (f"serve_window_paged_chunked_mesh{tag}", scfg(True, True))]
    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(9, 3), (11, 5)])]
    probe_prompts = {r.uid: rng.integers(0, GL.vocab, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    records, tokens_by_variant = [], {}
    for name, cfg in variants:
        srv = Server(GL, cfg, params)
        srv.serve(probe, probe_prompts)       # warm the launch shapes
        t0 = time.perf_counter()
        outs = srv.serve(reqs, prompts)
        wall = time.perf_counter() - t0
        st = {k: float(v) for k, v in srv.last_stats.items()}
        tokens_by_variant[name] = {o.uid: o.tokens for o in outs}
        emit(name, wall * 1e6,
             f"tokens_per_s_wall={st['tokens_per_s_wall']:.1f};"
             f"ttft_p95_ms={st['ttft_p95_ms']:.1f};"
             f"kv_retired_window={st['kv_retired_window']:.0f};"
             f"kv_retired_frontier={st['kv_retired_frontier']:.0f}")
        records.append({
            "name": name, "seed": seed,
            "mesh": mesh_spec if cfg.mesh is not None else "1x1",
            "batch_size": cfg.batch_size, "requests": n,
            "wall_s": wall,
            "gen_tokens": sum(len(o.tokens) for o in outs), **st,
        })

    by_name = {r["name"]: r for r in records}
    comparisons = {}
    for blocking, paged_name in [
            ("serve_window_blocking", "serve_window_paged_chunked"),
            (f"serve_window_blocking_mesh{(mesh_spec or '').lower()}",
             f"serve_window_paged_chunked_mesh{(mesh_spec or '').lower()}")]:
        if blocking not in by_name or paged_name not in by_name:
            continue
        rb, rp = by_name[blocking], by_name[paged_name]
        same = tokens_by_variant[blocking] == tokens_by_variant[paged_name]
        cmp = {
            "tokens_per_s_wall_blocking": rb["tokens_per_s_wall"],
            "tokens_per_s_wall_paged_chunked": rp["tokens_per_s_wall"],
            "speedup": rp["tokens_per_s_wall"]
            / max(rb["tokens_per_s_wall"], 1e-9),
            "ttft_p95_ratio": rp["ttft_p95_ms"]
            / max(rb["ttft_p95_ms"], 1e-9),
            "kv_retired_window": rp["kv_retired_window"],
            "kv_retired_frontier": rp["kv_retired_frontier"],
            "tokens_identical": bool(same),
        }
        comparisons[paged_name] = cmp
        emit(f"{paged_name}_vs_blocking", 0.0,
             f"speedup={cmp['speedup']:.2f}x;"
             f"ttft_p95_ratio={cmp['ttft_p95_ratio']:.2f};"
             f"tokens_identical={same}")

    if json_out:
        scenario = "serve_window" + ("_quick" if quick else "")
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_window_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def slo_bench(quick=False, seed=7, mesh_spec=None,
              json_out="artifacts/serve_bench.json", trace_out=None):
    """SLO-aware scheduling under overload (runtime/scheduler.py): a
    mixed-priority burst oversubscribes the slots 5-10x against a KV
    pool deliberately too small for the in-flight set, with every
    protected (priority-1) request arriving at the FIFO tail — the
    worst case for priority-blind admission.  Three serves per mesh
    variant:

      * slo   — tight pool + scheduler + priorities: the brownout
        ladder (defer -> preempt/swap -> shed) must complete the burst
        with ZERO PoolExhausted and ZERO protected-class sheds;
      * blind — same tight pool + scheduler but priorities stripped:
        the protected uids wait out the whole queue, so their p95 TTFT
        is the do-nothing baseline the scheduler must beat;
      * reference — unpressured pool, no scheduler: preemption and
        swap must be schedule-invisible, so every non-shed completion's
        tokens must be bit-identical to this serve.

    Records per-class TTFT, the full sched_* counter set, and the
    slo-vs-blind comparison into the deduped serve-bench JSON.  The slo
    serve runs with lifecycle tracing ON while ref and blind stay
    untraced, so tokens_identical doubles as the tracing-is-schedule-
    invisible acceptance; ``trace_out`` writes its Chrome trace
    (Perfetto-loadable, preempt/swap/resume spans + brownout-rung
    reason events) there."""
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.scheduler import SLOConfig
    from repro.runtime.server import Server, ServerConfig
    from repro.runtime.telemetry import TelemetryConfig, phase_breakdown

    SMALL = ModelConfig(name="serve-lm", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                        d_ff=256, vocab=256, pad_vocab_multiple=128,
                        dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), SMALL)
    rng = np.random.default_rng(seed)
    n = 20 if quick else 40                   # batch_size=4: 5x / 10x
    n_high = n // 4
    reqs, prompts = [], {}
    for i in range(n):
        plen = int(rng.integers(6, 30))
        prompts[i] = rng.integers(0, 256, size=(plen,)).astype(np.int32)
        reqs.append(Request(i, plen, int(rng.integers(6, 14)),
                            priority=1 if i >= n - n_high else 0))
    high_uids = {r.uid for r in reqs if r.priority == 1}
    blind = [Request(r.uid, r.prompt_len, r.max_new_tokens) for r in reqs]
    ccfg = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                        keep_recent=16, refresh_every=8)
    chunk = 8
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None

    # FIFO admission on every variant: clustered batching would reorder
    # the stream by traffic class and dilute the tail-arrival worst case
    def scfg(pool_blocks, sched, use_mesh, trace=False):
        return ServerConfig(
            batch_size=4, max_seq=96, kv_compress=ccfg,
            prefill_chunk=chunk, use_clustered_batching=False,
            paged=PagedKVConfig(block_size=4, pool_blocks=pool_blocks),
            scheduler=SLOConfig() if sched else None,
            telemetry=TelemetryConfig(trace=True) if trace else None,
            mesh=mesh if use_mesh else None)

    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(9, 3), (11, 5)])]
    probe_prompts = {r.uid: rng.integers(0, 256, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    def ttft_p95(outs, uids):
        vals = [o.prefill_ms for o in outs
                if o.uid in uids and not o.shed]
        return float(np.percentile(vals, 95)) if vals else float("inf")

    records, comparisons = [], {}
    variant_tags = [("", False)]
    if mesh is not None:
        variant_tags.append((f"_mesh{mesh_spec.lower()}", True))
    for tag, use_mesh in variant_tags:
        # the tight pool cannot hold the full slot provisioning (4
        # blocks/slot x slots/shard): admission-time block demand
        # collides with decode residency and the ladder has to act
        tight = 10 if not use_mesh else 8
        ref = Server(SMALL, scfg(48, False, use_mesh), params)
        ref.serve(probe, probe_prompts)       # warm the launch shapes
        ref_out = {o.uid: o.tokens for o in ref.serve(blind, prompts)}

        outs, walls, stats = {}, {}, {}
        phase_ms = {}
        for vname, stream in [("slo", reqs), ("blind", blind)]:
            srv = Server(SMALL, scfg(tight, True, use_mesh,
                                     trace=(vname == "slo")), params)
            srv.serve(probe, probe_prompts)
            t0 = time.perf_counter()
            outs[vname] = srv.serve(stream, prompts)
            walls[vname] = time.perf_counter() - t0
            stats[vname] = {k: float(v)
                            for k, v in srv.last_stats.items()}
            if vname == "slo":
                phase_ms = phase_breakdown(srv.last_trace)
                if trace_out:
                    os.makedirs(trace_out, exist_ok=True)
                    srv.export_trace(os.path.join(
                        trace_out, f"trace_slo{tag}.json"))

        same = all(o.tokens == ref_out[o.uid]
                   for o in outs["slo"] if not o.shed)
        shed_high = stats["slo"]["sched_shed_high"]
        p95_slo = ttft_p95(outs["slo"], high_uids)
        p95_blind = ttft_p95(outs["blind"], high_uids)
        for vname in ("slo", "blind"):
            st, name = stats[vname], f"serve_slo_{vname}{tag}"
            p95h = p95_slo if vname == "slo" else p95_blind
            emit(name, walls[vname] * 1e6,
                 f"ttft_p95_ms_high={p95h:.1f};"
                 f"preempts={st['sched_preemptions']:.0f};"
                 f"swaps_in={st['sched_swaps_in']:.0f};"
                 f"sheds={st['sched_sheds']:.0f};"
                 f"shed_high={st['sched_shed_high']:.0f}")
            records.append({
                "name": name, "seed": seed,
                "mesh": mesh_spec if use_mesh else "1x1",
                "batch_size": 4, "requests": n, "high_requests": n_high,
                "pool_blocks": tight, "wall_s": walls[vname],
                "ttft_p95_ms_high": p95h, **st,
                **({"phase_ms": phase_ms} if vname == "slo" else {}),
            })
        cmp = {
            "ttft_p95_ms_high_slo": p95_slo,
            "ttft_p95_ms_high_blind": p95_blind,
            "ttft_p95_high_ratio": p95_slo / max(p95_blind, 1e-9),
            "slo_beats_blind_ttft": bool(p95_slo < p95_blind),
            "preemptions": stats["slo"]["sched_preemptions"],
            "swaps_in": stats["slo"]["sched_swaps_in"],
            "sheds": stats["slo"]["sched_sheds"],
            "shed_high": shed_high,
            "tokens_identical": bool(same),
        }
        comparisons[f"serve_slo{tag}"] = cmp
        emit(f"serve_slo{tag}_vs_blind", 0.0,
             f"ttft_p95_high_ratio={cmp['ttft_p95_high_ratio']:.2f};"
             f"slo_beats_blind={cmp['slo_beats_blind_ttft']};"
             f"shed_high={shed_high:.0f};tokens_identical={same}")

    if json_out:
        scenario = "serve_slo" + ("_quick" if quick else "")
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_slo_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def recurrent_bench(quick=False, seed=7, mesh_spec=None,
                    json_out="artifacts/serve_bench.json", trace_out=None):
    """Recurrent-state serving (core/layer_state.py): a mamba2-style
    reduced hybrid config — the SSD reduced config with an interleaved
    clustered-ring attention layer, pattern 'GM' — served by the
    chunked + paged engine vs blocking one-at-a-time static decode.
    The layer-state-family exit pin as a benchmark: greedy tokens must
    be bit-identical across the two schedules, the per-family
    state-byte split (state_bytes_ring / state_bytes_recurrent) is
    recorded, and kv_retired_recurrent must stay 0 (fixed-size state
    folds every position; nothing retires).  ``--mesh 2x4`` adds the
    sharded chunked + paged variant, compared against the same
    single-device blocking oracle; ``--trace-out`` writes the paged
    serves' Chrome traces (state_families snapshot + lifecycle spans)."""
    import dataclasses as dc

    from repro import configs
    from repro.kernels.ops import interpret_default
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.server import Server, ServerConfig
    from repro.runtime.telemetry import TelemetryConfig

    # 'M'-only patterns serve dense (the pool holds nothing for
    # fixed-size state), so the paged leg needs one ring-family layer:
    # keep the reduced SSD mixer and interleave a clustered 'G' layer
    GM = dc.replace(
        configs.get_reduced("mamba2-2.7b"), name="mamba2-hybrid",
        family="hybrid", layer_pattern="GM", n_kv_heads=2, head_dim=16,
        d_ff=128, dtype="float32").validate()
    params = tfm.init_params(jax.random.PRNGKey(0), GM)
    rng = np.random.default_rng(seed)
    n = 4 if quick else 8
    reqs = [Request(i, int(rng.integers(8, 28)), int(rng.integers(4, 11)))
            for i in range(n)]
    prompts = {r.uid: rng.integers(0, GM.vocab, size=(r.prompt_len,))
               .astype(np.int32) for r in reqs}
    ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                        keep_recent=16, refresh_every=4)
    mesh = make_serving_mesh(mesh_spec) if mesh_spec else None

    def scfg(chunked_paged, use_mesh, trace=False):
        if not chunked_paged:
            # the exit-pin oracle: one request at a time, stepwise decode
            return ServerConfig(batch_size=1, engine="static",
                                use_clustered_batching=False)
        return ServerConfig(
            batch_size=4, max_seq=96, kv_compress=ccfg, prefill_chunk=8,
            paged=PagedKVConfig(block_size=4),
            telemetry=TelemetryConfig(trace=True) if trace else None,
            mesh=mesh if use_mesh else None)

    blocking = "serve_recurrent_blocking"
    variants = [(blocking, scfg(False, False)),
                ("serve_recurrent_paged_chunked",
                 scfg(True, False, trace=bool(trace_out)))]
    if mesh is not None:
        tag = mesh_spec.lower()
        variants.append((f"serve_recurrent_paged_chunked_mesh{tag}",
                         scfg(True, True, trace=bool(trace_out))))
    probe = [Request(10_000 + i, l, g)
             for i, (l, g) in enumerate([(9, 3), (11, 5)])]
    probe_prompts = {r.uid: rng.integers(0, GM.vocab, size=(r.prompt_len,))
                     .astype(np.int32) for r in probe}

    records, tokens_by_variant = [], {}
    for name, cfg in variants:
        srv = Server(GM, cfg, params)
        srv.serve(probe, probe_prompts)       # warm the launch shapes
        t0 = time.perf_counter()
        outs = srv.serve(reqs, prompts)
        wall = time.perf_counter() - t0
        st = {k: float(v) for k, v in srv.last_stats.items()}
        tokens_by_variant[name] = {o.uid: o.tokens for o in outs}
        gen = sum(len(o.tokens) for o in outs)
        # the static oracle publishes no engine stats — rate wall-side
        # so blocking and paged rows stay comparable
        emit(name, wall * 1e6,
             f"tok_per_s_wall={gen / max(wall, 1e-9):.1f};"
             f"state_bytes_ring={st.get('state_bytes_ring', 0):.0f};"
             f"state_bytes_recurrent="
             f"{st.get('state_bytes_recurrent', 0):.0f};"
             f"kv_retired_recurrent="
             f"{st.get('kv_retired_recurrent', 0):.0f}")
        if cfg.telemetry is not None and trace_out:
            os.makedirs(trace_out, exist_ok=True)
            suffix = name.removeprefix("serve_recurrent_paged_chunked")
            srv.export_trace(os.path.join(
                trace_out, f"trace_recurrent{suffix}.json"))
        records.append({
            "name": name, "seed": seed,
            "mesh": mesh_spec if cfg.mesh is not None else "1x1",
            "batch_size": cfg.batch_size, "requests": n,
            "wall_s": wall, "gen_tokens": gen,
            "tok_per_s_wall": gen / max(wall, 1e-9),
            "state_bytes_ring": st.get("state_bytes_ring", 0.0),
            "state_bytes_recurrent": st.get("state_bytes_recurrent", 0.0),
            "kv_retired_recurrent": st.get("kv_retired_recurrent", 0.0),
            **st,
        })

    by_name = {r["name"]: r for r in records}
    comparisons = {}
    for pname in [v for v, _ in variants if v != blocking]:
        rb, rp = by_name[blocking], by_name[pname]
        same = tokens_by_variant[blocking] == tokens_by_variant[pname]
        cmp = {
            "tok_per_s_wall_blocking": rb["tok_per_s_wall"],
            "tok_per_s_wall_paged_chunked": rp["tok_per_s_wall"],
            "speedup": rp["tok_per_s_wall"]
            / max(rb["tok_per_s_wall"], 1e-9),
            "state_bytes_ring": rp["state_bytes_ring"],
            "state_bytes_recurrent": rp["state_bytes_recurrent"],
            "kv_retired_recurrent": rp["kv_retired_recurrent"],
            "tokens_identical": bool(same),
        }
        comparisons[pname] = cmp
        emit(f"{pname}_vs_blocking", 0.0,
             f"speedup={cmp['speedup']:.2f}x;"
             f"state_bytes_recurrent={cmp['state_bytes_recurrent']:.0f};"
             f"kv_retired_recurrent={cmp['kv_retired_recurrent']:.0f};"
             f"tokens_identical={same}")

    if json_out:
        scenario = "serve_recurrent" + ("_quick" if quick else "")
        run_key = {"git_sha": _git_sha(), "seed": seed,
                   "mesh": mesh_spec or "1x1", "scenario": scenario}
        n_runs = _append_serve_json(json_out, run_key, {
            "quick": bool(quick), "timestamp": time.time(),
            "backend": jax.default_backend(),
            "pallas_interpret": bool(interpret_default()),
            "records": records, "comparisons": comparisons})
        emit("serve_recurrent_json", 0.0,
             f"runs={n_runs};records={len(records)};path={json_out}")


def roofline_summary(quick=False):
    arts = sorted(glob.glob("artifacts/dryrun/*.json"))
    if not arts:
        emit("roofline_summary", 0.0, "no_artifacts_run_dryrun_first")
        return
    from repro.roofline import analysis
    n_ok = n_skip = 0
    worst = None
    for p in arts:
        with open(p) as fh:
            rec = json.load(fh)
        if rec.get("mesh") != "16x16":
            continue
        if "skipped" in rec:
            n_skip += 1
            continue
        r = analysis.analyze_record(rec)
        if r is None:
            continue
        n_ok += 1
        if worst is None or r["roofline_fraction"] < worst["roofline_fraction"]:
            worst = r
    emit("roofline_summary", 0.0,
         (f"cells_ok={n_ok};skipped={n_skip};"
          f"worst={worst['arch']}x{worst['shape']}"
          f"@{worst['roofline_fraction']:.3f}") if worst else "none")


BENCHES = [t1_median_throughput, t2_recognition_rate, t3_fixed_point,
           t4_optimal_k, t5_kmedians_end2end, kv_compress_bench,
           request_batching_bench, grad_compress_bench, serve_bench,
           prefix_share_bench, template_store_bench, window_bench,
           slo_bench, recurrent_bench, roofline_summary]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("scenario", nargs="?", default=None,
                    help="run only benchmarks whose name contains this "
                         "(e.g. 'serve'); same filter as --only")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=7,
                    help="request-stream seed for the serve scenario "
                         "(recorded in its JSON output)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serving mesh for the serve scenario, "
                         "e.g. 2x4 (CPU fake devices are forced "
                         "automatically)")
    ap.add_argument("--json-out", default="artifacts/serve_bench.json",
                    help="where the serve scenario writes its JSON records")
    ap.add_argument("--paged", action="store_true",
                    help="add paged-engine variants to the serve scenario "
                         "(block-pool KV tails + packed ragged launches); "
                         "records padded-compute waste vs the dense "
                         "bucketed path")
    ap.add_argument("--trace-out", default=None,
                    help="directory where the traced scenarios (slo, "
                         "template_store, recurrent) write Chrome "
                         "trace-event JSON (Perfetto-loadable "
                         "request-lifecycle timelines)")
    args = ap.parse_args()
    only = args.only or args.scenario
    print("name,us_per_call,derived")
    for b in BENCHES:
        if only and only not in b.__name__:
            continue
        if b is serve_bench:
            b(quick=args.quick, seed=args.seed, mesh_spec=args.mesh,
              json_out=args.json_out, paged=args.paged)
        elif b in (template_store_bench, slo_bench, recurrent_bench):
            b(quick=args.quick, seed=args.seed, mesh_spec=args.mesh,
              json_out=args.json_out, trace_out=args.trace_out)
        elif b in (prefix_share_bench, window_bench):
            b(quick=args.quick, seed=args.seed, mesh_spec=args.mesh,
              json_out=args.json_out)
        else:
            b(quick=args.quick)


if __name__ == "__main__":
    main()
