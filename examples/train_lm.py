"""End-to-end driver: train a small LM for a few hundred steps on CPU.

Exercises the full production path: config → sharded-ready model →
AdamW + schedule → deterministic data pipeline → checkpoint/resume →
straggler stats — the same code the multi-pod launcher runs, at a size a
CPU finishes in minutes.  Optionally enables the paper's k-means-codebook
gradient compression to show the convergence impact is negligible.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--compress]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import grad_compress
from repro.data import pipeline
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

# ~15M params: finishes a few hundred CPU steps in minutes; same family
# as the assigned dense archs (GQA + SwiGLU + RoPE)
SMALL = ModelConfig(name="small-lm", family="dense", n_layers=4, d_model=256,
                    n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024,
                    vocab=2048, pad_vocab_multiple=128, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compress", action="store_true",
                    help="cross-pod k-means gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    aw = adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    gt = (grad_compress.make_grad_transform(grad_compress.CompressConfig())
          if args.compress else None)

    def loss_fn(params, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return tfm.train_loss(params, SMALL, b, remat=False)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(grads, opt_state, params, aw,
                                             grad_transform=gt)
        return params, opt_state, dict(metrics, loss=loss, **om)

    data = pipeline.SyntheticLM(SMALL, pipeline.DataConfig(
        seed=0, global_batch=args.batch, seq_len=args.seq))
    tcfg = TrainerConfig(n_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=max(50, args.steps // 4), log_every=20)
    trainer = Trainer(SMALL, tcfg, aw, step_fn, data)
    trainer.run()
    n = len(trainer.losses)
    print(f"[example] loss: {trainer.losses[0]:.3f} → "
          f"{sum(trainer.losses[-5:]) / 5:.3f} over {n} steps"
          + (" (with gradient compression)" if args.compress else ""))


if __name__ == "__main__":
    main()
