"""Serving example: continuous batching + clustered-KV compression.

1. a queue of mixed-length requests is clustered into a padding-minimal
   admission order (bit-serial k-medians over (prompt_len, gen_len)
   features) — padding waste vs FIFO is reported,
2. a slot-based continuous batcher admits requests as decode slots free
   and serves them with a small dense LM (per-slot positions, early exit
   at each request's own token budget),
3. the same queue is re-served from a clustered KV cache that is
   re-compacted mid-stream (batched bit-serial k-medians, fused Pallas
   clustered_decode attention) — the "memory management" half of the
   title — and the standalone compression error vs exact attention is
   reported alongside the memory ratio,
4. when more than one device is visible, the same queue runs once more on
   a (data, model) serving mesh — decode slots shard over `data`,
   attention heads over `model` — and token parity with the single-device
   run is reported (it is bit-exact by construction).

Run: PYTHONPATH=src python examples/serve_clustered_kv.py

Mesh-enabled run (8 fake CPU devices → a 2x4 serving mesh):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_clustered_kv.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kv_compress
from repro.core.request_cluster import Request, plan_batches, plan_fifo
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.server import Server, ServerConfig

SMALL = ModelConfig(name="serve-lm", family="dense", n_layers=4, d_model=128,
                    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=512,
                    vocab=512, pad_vocab_multiple=128, dtype="float32")


def main():
    rng = np.random.default_rng(0)
    params = tfm.init_params(jax.random.PRNGKey(0), SMALL)

    # --- request processing: clustered batching ---
    lens = np.where(rng.random(24) < 0.5,
                    rng.integers(8, 24, 24), rng.integers(96, 160, 24))
    reqs = [Request(i, int(l), 8) for i, l in enumerate(lens)]
    fifo = plan_fifo(reqs, batch_size=4)
    clus = plan_batches(reqs, batch_size=4)
    print(f"[batcher] padding waste: fifo {fifo.waste * 100:.1f}% → "
          f"clustered {clus.waste * 100:.1f}%")

    srv = Server(SMALL, ServerConfig(batch_size=4, max_seq=256), params)
    prompts = {r.uid: rng.integers(0, 512, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    outs = srv.serve(reqs, prompts)
    st = srv.last_stats
    print(f"[server] continuous batching: {len(outs)} completions, "
          f"{st['tokens_per_s']:.1f} tok/s, slot waste "
          f"{st['slot_waste'] * 100:.1f}%")

    # --- chunked prefill interleaved with decode (--prefill-chunk) ---
    # Admission stops blocking the decode loop: each engine step feeds one
    # 16-token prompt chunk for at most one admitting slot per data shard,
    # fused into the decode launch (mixed-mode Pallas clustered_decode).
    # Greedy tokens stay identical to blocking admission; TTFT collapses
    # because decode slots never wait for a prefill call, and the
    # bucketed-launch stats show the drain tail shrinking the decode
    # launch once the queue empties.
    srv_k = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       prefill_chunk=16), params)
    outs_k = srv_k.serve(reqs, prompts)
    same = all(a.tokens == b.tokens for a, b in
               zip(sorted(outs_k, key=lambda o: o.uid),
                   sorted(outs, key=lambda o: o.uid)))
    st = srv_k.last_stats
    print(f"[server] chunked prefill (--prefill-chunk 16): "
          f"{st['tokens_per_s_wall']:.1f} tok/s wall, TTFT p50/p95 "
          f"{st['ttft_p50_ms']:.0f}/{st['ttft_p95_ms']:.0f} ms, "
          f"{st['prefill_chunks']:.0f} chunks, tokens "
          f"{'identical' if same else 'DIVERGED'} vs blocking admission")
    print(f"[server] bucketed launches: mean bucket "
          f"{st['launch_bucket_mean']:.2f} slots/shard "
          f"({st['launch_rows_frac'] * 100:.0f}% of slots launched per "
          f"step; the drain tail stops paying for empty slots)")

    # same queue served from a clustered KV cache with mid-stream
    # compaction (fused Pallas clustered_decode, interpret mode on CPU);
    # prefill_chunk additionally streams long prompts straight into
    # clustered form via kv_compress.absorb_chunk (compaction-aware
    # admission: no exact prompt KV is ever materialized)
    ccfg = kv_compress.KVCompressConfig(n_clusters=24, iters=4,
                                        keep_recent=32, refresh_every=16)
    srv_c = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16),
                   params)
    outs_c = srv_c.serve(reqs, prompts)
    agree = np.mean([np.mean(np.array(a.tokens[:len(b.tokens)])
                             == np.array(b.tokens[:len(a.tokens)]))
                     for a, b in zip(sorted(outs_c, key=lambda o: o.uid),
                                     sorted(outs, key=lambda o: o.uid))])
    print(f"[server] clustered-KV + compaction (+chunked admission, "
          f"{srv_c.last_stats['kv_absorbs']:.0f} absorbs): "
          f"{srv_c.last_stats['tokens_per_s']:.1f} tok/s, token agreement "
          f"vs exact serving {agree * 100:.0f}%")

    # --- paged clustered-KV memory manager (ServerConfig.paged) ---
    # The engines above allocate every slot's exact tail as a full dense
    # ring.  The paged engine instead draws fixed-size blocks from a
    # shared per-shard pool behind per-slot block tables
    # (runtime/kv_pool.py): blocks map lazily right before the write that
    # needs them, recycle the moment a request exits, and return to the
    # pool mid-stream once compaction's coverage frontier passes them.
    # Decode runs as PACKED ragged launches — one row per real
    # (slot, position) pair via the Pallas paged_clustered_decode kernel
    # gathering tail blocks through the block table — so mixed
    # prefill+decode compute scales with real tokens instead of
    # slots × chunk (PagedAttention-style).  Greedy tokens stay
    # bit-identical to the dense clustered engine.
    from repro.runtime.kv_pool import PagedKVConfig
    srv_p = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16,
                                       paged=PagedKVConfig(block_size=8)),
                   params)
    outs_p = srv_p.serve(reqs, prompts)
    same_p = all(a.tokens == b.tokens for a, b in
                 zip(sorted(outs_p, key=lambda o: o.uid),
                     sorted(outs_c, key=lambda o: o.uid)))
    stp, stc = srv_p.last_stats, srv_c.last_stats
    print(f"[server] paged KV (8-pos blocks): tokens "
          f"{'identical' if same_p else 'DIVERGED'} vs dense clustered; "
          f"launch padding {stp['launch_pad_frac'] * 100:.0f}% vs dense "
          f"{stc['launch_pad_frac'] * 100:.0f}%, pool peak "
          f"{stp['pool_occupancy_peak'] * 100:.0f}% of "
          f"{stp['pool_blocks_total']:.0f} blocks "
          f"({stp['pool_allocs']:.0f} allocs / {stp['pool_frees']:.0f} "
          f"frees, {stp['pool_blocks_end']:.0f} still held at drain)")

    # --- prefix-shared paged admission (ServerConfig.prefix_share) ---
    # Bursty templated traffic: many prompts = one shared template + a
    # short unique suffix.  The paged engine's block tables + ref counts
    # let admissions share structure ACROSS requests: chunked admission
    # registers each prompt's prefix state (live tail blocks + absorbed
    # centroids + coverage frontier) at chunk boundaries into a per-shard
    # prefix cache (runtime/prefix_cache.py), and a later request whose
    # prompt matches adopts those blocks and restores that state instead
    # of re-streaming the template — copy-on-write at the first divergent
    # ring write keeps shared payloads immutable.  Greedy tokens stay
    # bit-identical to unshared paged serving (the reused state is
    # exactly what the unshared run would recompute from the same
    # tokens); TTFT collapses because shared-prefix chunks are never
    # fed, and the template's tail blocks exist once per shard instead
    # of once per slot (kv_bytes_saved).  Note the physical peak can
    # still RISE here: admissions that skip the template finish ~5x
    # sooner, so more requests decode concurrently — the engine trades
    # the saved bytes for throughput (benchmarks/run.py prefix_share
    # pins a regime where both p95 TTFT and physical peak KV drop).
    from repro.runtime.prefix_cache import PrefixShareConfig
    tpl = rng.integers(0, 512, size=(96,)).astype(np.int32)
    tpl_reqs, tpl_prompts = [], {}
    for i in range(12):
        sfx = rng.integers(0, 512, size=(int(rng.integers(4, 12)),))
        tpl_prompts[i] = np.concatenate([tpl, sfx]).astype(np.int32)
        tpl_reqs.append(Request(i, len(tpl_prompts[i]), 8))
    srv_u = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16,
                                       paged=PagedKVConfig(block_size=8)),
                   params)
    outs_u = srv_u.serve(tpl_reqs, tpl_prompts)
    srv_s = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16,
                                       paged=PagedKVConfig(block_size=8),
                                       prefix_share=PrefixShareConfig()),
                   params)
    outs_s = srv_s.serve(tpl_reqs, tpl_prompts)
    same_s = all(a.tokens == b.tokens for a, b in
                 zip(sorted(outs_s, key=lambda o: o.uid),
                     sorted(outs_u, key=lambda o: o.uid)))
    stu, sts = srv_u.last_stats, srv_s.last_stats
    print(f"[server] prefix sharing (96-token template x "
          f"{len(tpl_reqs)} requests): tokens "
          f"{'identical' if same_s else 'DIVERGED'} vs unshared paged; "
          f"{sts['prefix_hits']:.0f} hits reused "
          f"{sts['prefix_tokens_reused']:.0f} prompt tokens, TTFT p95 "
          f"{sts['ttft_p95_ms']:.0f} vs {stu['ttft_p95_ms']:.0f} ms, "
          f"{sts['kv_bytes_saved'] / 1024:.0f} KiB of tail KV shared, "
          f"{sts['pool_cow']:.0f} copy-on-write swaps (physical peak "
          f"{sts['kv_bytes_peak_per_shard'] / 1024:.0f} vs "
          f"{stu['kv_bytes_peak_per_shard'] / 1024:.0f} KiB/shard — "
          f"faster admission keeps more requests in flight)")

    # --- persistent template store (ServerConfig.template_store) ---
    # The prefix cache above dies with its serve() call: a second burst
    # of the same template re-pays the whole template prefill.  The
    # template store (runtime/template_store.py) hoists the cache to the
    # Server — entries and the pool blocks they pin survive the
    # inter-stream drain, so a LATER serve of the same templated traffic
    # starts warm: every admission adopts the boundary registered by the
    # previous serve from its first engine step.  The store also
    # clusters the live traffic online (Mettu–Plaxton-style medoid
    # promotion over prefix digests) and steers same-cluster requests
    # onto the shards already holding their blocks.  Two things to know:
    # the pool needs headroom above full slot provisioning (pinned
    # entries live in the surplus — a zero-surplus pool pressure-evicts
    # every entry before the drain), and tokens stay bit-identical
    # because a snapshot is only adopted under the exact config epoch +
    # verified token match that produced it.
    from repro.runtime.template_store import TemplateStoreConfig
    tpl_reqs2, tpl_prompts2 = [], {}
    for i in range(12):
        sfx = rng.integers(0, 512, size=(int(rng.integers(4, 12)),))
        tpl_prompts2[i] = np.concatenate([tpl, sfx]).astype(np.int32)
        tpl_reqs2.append(Request(i, len(tpl_prompts2[i]), 8))
    srv_t = Server(SMALL, ServerConfig(
        batch_size=4, max_seq=256, kv_compress=ccfg, prefill_chunk=16,
        paged=PagedKVConfig(block_size=8, pool_blocks=24),
        template_store=TemplateStoreConfig(max_entries=2)), params)
    srv_t.serve(tpl_reqs, tpl_prompts)        # serve #1 fills the store
    st1 = dict(srv_t.last_stats)
    outs_t = srv_t.serve(tpl_reqs2, tpl_prompts2)   # serve #2: warm
    st2 = srv_t.last_stats
    srv_ref = Server(SMALL, ServerConfig(
        batch_size=4, max_seq=256, kv_compress=ccfg, prefill_chunk=16,
        paged=PagedKVConfig(block_size=8, pool_blocks=24)), params)
    outs_ref = srv_ref.serve(tpl_reqs2, tpl_prompts2)  # cold reference
    ref_uid = {o.uid: o.tokens for o in outs_ref}
    same_t = all(o.tokens == ref_uid[o.uid] for o in outs_t)
    print(f"[server] template store (persistent across serves): warm "
          f"serve TTFT p95 {st2['ttft_p95_ms']:.0f} ms vs "
          f"{st1['ttft_p95_ms']:.0f} ms for the store-filling serve, "
          f"{st2['prefix_hits']:.0f} warm hits reused "
          f"{st2['prefix_tokens_reused']:.0f} prompt tokens, tokens "
          f"{'identical' if same_t else 'DIVERGED'} vs a cold store")
    print(f"[server] store state: {st2['template_entries']:.0f} entries "
          f"pinning {st2['template_pinned_blocks']:.0f} blocks between "
          f"serves ({st2['template_bytes_pinned'] / 1024:.0f} KiB), "
          f"{st2['template_clusters']:.0f} traffic clusters, cohesion "
          f"{st2['template_cohesion_mean']:.2f}")
    srv_t.invalidate_templates()              # drains the pool to zero

    # --- SLO-aware scheduling (ServerConfig.scheduler) ---
    # Overload changes the question from "how fast?" to "who eats the
    # shortage?".  Each Request carries a priority (and optional TTFT
    # deadline); the paged engine plus an SLOConfig walks a brownout
    # ladder when the block pool can't back every in-flight request:
    # defer the admission, then PREEMPT a lower-priority slot — its
    # tail-ring blocks and clustered centroid snapshot are gathered to
    # host memory, its blocks freed, and it resumes mid-stream later,
    # bit-identically, because per-slot state is a deterministic
    # function of the slot's own token stream — and only then shed
    # best-effort work.  The protected class is never shed.  Here the
    # same queue runs priority-tagged (high class arriving LAST, the
    # FIFO worst case) against a pool ~40% under full provisioning;
    # non-shed tokens must match the unpressured paged serve above.
    from repro.runtime.scheduler import SLOConfig
    sreqs = [Request(r.uid, r.prompt_len, r.max_new_tokens,
                     priority=1 if r.uid >= 18 else 0) for r in reqs]
    srv_s = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16,
                                       paged=PagedKVConfig(block_size=8,
                                                           pool_blocks=10),
                                       scheduler=SLOConfig()), params)
    outs_s = srv_s.serve(sreqs, prompts)
    sts = srv_s.last_stats
    p_uid = {o.uid: o.tokens for o in outs_p}
    same_s = all(o.tokens == p_uid[o.uid] for o in outs_s if not o.shed)
    hi_ttft = [o.prefill_ms for o in outs_s if o.uid >= 18 and not o.shed]
    print(f"[server] SLO scheduling (pool 10/16 blocks, 6 priority-1 at "
          f"the tail): {sts['sched_preemptions']:.0f} preemptions, "
          f"{sts['sched_swaps_in']:.0f} swap-ins, "
          f"{sts['sched_deferrals']:.0f} deferrals, "
          f"{sts['sched_sheds']:.0f} best-effort shed "
          f"({sts['sched_shed_high']:.0f} protected shed); priority-1 "
          f"TTFT p95 {np.percentile(hi_ttft, 95):.0f} ms; non-shed "
          f"tokens {'identical' if same_s else 'DIVERGED'} vs the "
          f"unpressured paged serve")

    # --- observability (ServerConfig.telemetry) ---
    # Every number printed above came out of `server.last_stats` — which
    # is now a flat view over a typed metrics registry (`server.metrics`,
    # runtime/telemetry.py): counters, gauges, and histograms with help
    # strings, re-registered each serve so dynamic keys (per-cluster,
    # per-shard, sched_*) can never leak across serves.  Turning on
    # TelemetryConfig(trace=True) additionally records the request
    # LIFECYCLE: queued → admit → prefill chunks → first token → decode
    # → compact/absorb → preempt/swap-out → resume → finish/shed, plus
    # one span per engine step (launch kind, rows, pool occupancy) and a
    # brownout event naming the rung and WHY whenever the SLO ladder
    # acts.  Tracing is host-side only — greedy tokens are bit-identical
    # with it on or off — and `export_trace()` writes a Chrome
    # trace-event file loadable in Perfetto / chrome://tracing (one
    # process per data shard, one thread per decode slot).
    from repro.runtime.telemetry import (TelemetryConfig, phase_breakdown,
                                         validate_trace)
    srv_o = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                       kv_compress=ccfg, prefill_chunk=16,
                                       paged=PagedKVConfig(block_size=8,
                                                           pool_blocks=10),
                                       scheduler=SLOConfig(),
                                       telemetry=TelemetryConfig(
                                           trace=True)), params)
    outs_o = srv_o.serve(sreqs, prompts)
    traced_same = ({o.uid: o.tokens for o in outs_o}
                   == {o.uid: o.tokens for o in outs_s})
    evs = srv_o.last_trace
    problems = validate_trace(evs, totals=srv_o.last_stats)
    kinds = sorted({e["name"] for e in evs})
    ph = phase_breakdown(evs)
    print(f"[telemetry] traced serve: {len(evs)} events "
          f"({', '.join(kinds)}), schema problems: {len(problems)}, "
          f"tokens {'identical' if traced_same else 'DIVERGED'} vs the "
          f"untraced serve")
    print("[telemetry] phase breakdown: " + ", ".join(
        f"{k.removeprefix('phase_').removesuffix('_ms')} {v:.0f} ms"
        for k, v in ph.items()))
    # srv_o.export_trace("slo_trace.json") writes the Perfetto timeline;
    # the registry documents itself — the serving metrics reference:
    table = srv_o.metrics.reference_table()
    print(f"[telemetry] metrics reference ({len(table.splitlines()) - 2} "
          f"metrics; first rows):")
    for line in table.splitlines()[:6]:
        print("    " + line)

    # --- sliding-window serving (RetentionPolicy opens the model zoo) ---
    # Everything above serves an all-global-attention model, where "which
    # ring positions may be dropped?" is answered by the clustered
    # coverage frontier.  That question now lives behind a per-layer
    # RetentionPolicy (core/retention.py), so gemma2/3-style models with
    # alternating local ('L') sliding-window layers serve through the
    # SAME chunked + paged engine: 'G' layers keep FrontierRetention
    # (centroids + cov frontier, unchanged), while each 'L' layer holds a
    # dense window-sized ring under WindowRetention — positions retire
    # the moment they fall more than `sliding_window` steps behind, the
    # pool reclaims their blocks mid-stream, and the paged decode kernel
    # applies the per-row window floor (wlo) alongside the cov mask.
    # Greedy tokens stay bit-identical to blocking dense admission.
    # (QuotaRetention, the third policy, gives un-clustered paged exact
    # KV a per-slot block budget — see benchmarks/run.py serve --paged
    # without --kv-* flags and tests/test_serving_engine.py.)
    import dataclasses as dc
    GLWIN = dc.replace(SMALL, name="serve-lm-gl", layer_pattern="GL",
                       sliding_window=16)
    params_w = tfm.init_params(jax.random.PRNGKey(1), GLWIN)
    w_reqs = [Request(i, int(rng.integers(8, 28)), 8) for i in range(12)]
    w_prompts = {r.uid: rng.integers(0, 512, size=(r.prompt_len,)).astype(
        np.int32) for r in w_reqs}
    ccfg_w = kv_compress.KVCompressConfig(n_clusters=8, iters=4,
                                          keep_recent=32, refresh_every=8)
    srv_wb = Server(GLWIN, ServerConfig(batch_size=4, max_seq=96,
                                        kv_compress=ccfg_w), params_w)
    outs_wb = srv_wb.serve(w_reqs, w_prompts)
    srv_w = Server(GLWIN, ServerConfig(batch_size=4, max_seq=96,
                                       kv_compress=ccfg_w, prefill_chunk=8,
                                       paged=PagedKVConfig(block_size=8)),
                   params_w)
    outs_w = srv_w.serve(w_reqs, w_prompts)
    same_w = all(a.tokens == b.tokens for a, b in
                 zip(sorted(outs_w, key=lambda o: o.uid),
                     sorted(outs_wb, key=lambda o: o.uid)))
    stw = srv_w.last_stats
    print(f"[server] sliding-window model ('GL' x2, window=16, chunked + "
          f"paged): tokens {'identical' if same_w else 'DIVERGED'} vs "
          f"blocking dense; window retired {stw['kv_retired_window']:.0f} "
          f"positions, frontier retired {stw['kv_retired_frontier']:.0f}, "
          f"{stw['pool_blocks_end']:.0f} blocks held at drain")

    # --- recurrent-state serving (layer-state families open mamba2) ---
    # RetentionPolicy answers "which ring positions may drop?", but a
    # mamba2 ('M') or RG-LRU ('R') layer holds no ring at all — its
    # per-slot state is a fixed-size (conv window, state matrix) pair.
    # core/layer_state.py names that split: every layer belongs to a
    # LayerState family, RingKVState ('G'/'L', retention-governed,
    # pool-backed when paged) or RecurrentState ('M'/'R', advanced inside
    # the same mixed prefill+decode launch, snapshotted whole).  A hybrid
    # 'GM' model therefore serves through the SAME chunked + paged engine
    # — 'G' layers cluster and page as above while the 'M' layer's state
    # rides along — and greedy tokens stay bit-identical to blocking
    # one-at-a-time decode.  Checkpoints carry both families, so
    # prefix-sharing and preempt -> swap -> resume work unchanged (the
    # recurrent state's bytes are priced into the swap ledger).
    from repro.models.config import SSMConfig
    GMREC = ModelConfig(name="serve-lm-gm", family="hybrid", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                        d_ff=128, vocab=512, pad_vocab_multiple=128,
                        dtype="float32", layer_pattern="GM",
                        ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                      head_dim=32, n_groups=1, chunk=32))
    params_r = tfm.init_params(jax.random.PRNGKey(2), GMREC)
    r_reqs = [Request(i, int(rng.integers(8, 28)), 8) for i in range(8)]
    r_prompts = {r.uid: rng.integers(0, 512, size=(r.prompt_len,)).astype(
        np.int32) for r in r_reqs}
    srv_rb = Server(GMREC, ServerConfig(batch_size=1, engine="static",
                                        use_clustered_batching=False),
                    params_r)
    outs_rb = srv_rb.serve(r_reqs, r_prompts)
    srv_r = Server(GMREC, ServerConfig(batch_size=4, max_seq=96,
                                       kv_compress=ccfg_w, prefill_chunk=8,
                                       paged=PagedKVConfig(block_size=8)),
                   params_r)
    outs_r = srv_r.serve(r_reqs, r_prompts)
    same_r = all(a.tokens == b.tokens for a, b in
                 zip(sorted(outs_r, key=lambda o: o.uid),
                     sorted(outs_rb, key=lambda o: o.uid)))
    str_ = srv_r.last_stats
    print(f"[server] hybrid recurrent model ('GM', chunked + paged): tokens "
          f"{'identical' if same_r else 'DIVERGED'} vs blocking decode; "
          f"state bytes/slot ring {str_['state_bytes_ring']:.0f} / "
          f"recurrent {str_['state_bytes_recurrent']:.0f}, recurrent "
          f"retired {str_['kv_retired_recurrent']:.0f} (fixed-size state "
          f"never retires), {str_['pool_blocks_end']:.0f} blocks at drain")

    # --- mesh-sharded serving (slots x tensor parallel) ---
    # With N>1 visible devices (XLA_FLAGS above) the same queue is served
    # on a (data, model) mesh: the engine cache becomes sharded arrays
    # (slots over data, kv heads over model), the Pallas clustered_decode
    # kernel dispatches per shard via shard_map, and greedy tokens stay
    # bit-identical to the single-device run.
    n_dev = len(jax.devices())
    if n_dev > 1:
        from repro.launch.mesh import make_serving_mesh
        model_par = 4 if n_dev % 8 == 0 else 2
        spec = f"{n_dev // model_par}x{model_par}"
        mesh = make_serving_mesh(spec)
        srv_m = Server(SMALL, ServerConfig(batch_size=4, max_seq=256,
                                           kv_compress=ccfg,
                                           prefill_chunk=16, mesh=mesh),
                       params)
        outs_m = srv_m.serve(reqs, prompts)
        by_uid = {o.uid: o.tokens for o in outs_c}
        exact = all(o.tokens == by_uid[o.uid] for o in outs_m)
        print(f"[server] mesh {spec}: "
              f"{srv_m.last_stats['tokens_per_s']:.1f} tok/s, tokens "
              f"{'bit-identical' if exact else 'DIVERGED'} vs single-device")
    else:
        print("[server] mesh serving skipped (1 device; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 to try a 2x4 mesh)")

    # --- memory management: clustered-KV compression ---
    long_prompt = rng.integers(0, 512, size=(1, 192)).astype(np.int32)
    _, cache = jax.jit(lambda tk: tfm.prefill(params, SMALL, tk,
                                              max_seq=256))(
        jnp.asarray(long_prompt))
    kc = np.asarray(cache["scan"]["sub0"]["k"])[0, 0]    # (S, H, Dh) layer 0
    vc = np.asarray(cache["scan"]["sub0"]["v"])[0, 0]
    kj, vj = jnp.asarray(kc[:192]), jnp.asarray(vc[:192])
    cfg = kv_compress.KVCompressConfig(n_clusters=24, iters=8,
                                       keep_recent=32)
    ckv = kv_compress.compress_cache(kj, vj, cfg)
    q = jnp.asarray(rng.normal(size=(SMALL.n_kv_heads,
                                     SMALL.head_dim)).astype(np.float32))
    out_c = kv_compress.clustered_attention(q, ckv, scale=SMALL.head_dim**-0.5)
    out_e = kv_compress.exact_attention(q, kj, vj,
                                        scale=SMALL.head_dim**-0.5)
    err = float(jnp.linalg.norm(out_c - out_e) / jnp.linalg.norm(out_e))
    print(f"[kv] 192 keys → {cfg.n_clusters} median centroids + "
          f"{cfg.keep_recent} exact tail: memory "
          f"{kv_compress.memory_ratio(192, cfg):.1f}× smaller, "
          f"attention rel-err {err:.3f}")


if __name__ == "__main__":
    main()
