"""Quickstart: the paper's pipeline end to end on a paper-style table.

1. load the wine-quality-style table (paper §4 attributes),
2. convert to fixed point (paper's 2^f scaling),
3. cluster with bit-serial k-MEDIANS (the aggregations variant) and with
   plain k-means, on CPU,
4. sweep k with the avgBMP loop (paper's optimal-k search),
5. report recognition rates + the median-vs-mean robustness gap.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import bitserial, clustering, quantizer
from repro.core.clustering import ClusterConfig
from repro.data import pipeline


def main():
    x, y = pipeline.wine_like(n=1500, seed=0)
    xs = (x - x.mean(0)) / (x.std(0) + 1e-6)
    xj = jnp.asarray(xs)
    print(f"table: {x.shape[0]} rows × {x.shape[1]} features "
          f"({', '.join(pipeline.WINE_FEATURES[:4])}, …)")

    # --- fixed-point front end (paper §4) ---
    scale = quantizer.auto_scale(xj, bits=32)
    print(f"fixed-point scales (2^f per feature): "
          f"{np.asarray(jnp.log2(scale)).astype(int)[:6]}…")

    # --- bit-serial median of every feature ---
    med = bitserial.median(xj, bits=32)
    print(f"bit-serial medians ≈ {np.round(np.asarray(med), 3)[:4]}… "
          f"(vs numpy {np.round(np.median(xs, 0), 3)[:4]}…)")

    # --- k-medians (paper) vs k-means ---
    for name, cfg in [
        ("k-medians (bit-serial)", ClusterConfig(k=3, centroid="median",
                                                 metric="l1", seed=1)),
        ("k-means (baseline)", ClusterConfig(k=3, centroid="mean",
                                             metric="l2", seed=1)),
    ]:
        res = clustering.fit(xj, cfg)
        rate = clustering.recognition_rate(res.assign, jnp.asarray(y), 3, 3)
        print(f"{name}: {int(res.n_iters)} iters, "
              f"recognition {float(rate) * 100:.1f}%, "
              f"cluster sizes {np.asarray(res.counts).astype(int)}")

    # --- optimal-k search (paper §4) on the census-style table ---
    xc, yc = pipeline.census_like(n=1200, seed=1, outlier_frac=0.0)
    k_opt, scores = clustering.select_k(
        jnp.asarray(xc), 2, 8, ClusterConfig(k=2, centroid="mean",
                                             metric="l2"))
    print(f"avgBMP k-sweep (census-like, true k=5) scores: "
          f"{[round(s, 3) for s in scores]} → k* = {k_opt}")


if __name__ == "__main__":
    main()
