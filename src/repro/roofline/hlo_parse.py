"""HLO-text cost analyzer with loop trip-count accounting.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified on this
jaxlib), which silently drops ~n_layers× of the FLOPs/bytes/collectives for
scanned models.  This module re-derives the three roofline inputs from the
post-optimization, SPMD-partitioned HLO text:

  * flops       — dot/conv exact (2·M·N·K from contracting dims), 1 flop/elem
                  for elementwise, operand-size for reduces,
  * hbm bytes   — operands+results at fusion boundaries (fusion bodies are
                  on-chip), parameters/tuples/copies of views excluded,
  * collective bytes — per kind (all-reduce, all-gather, reduce-scatter,
                  all-to-all, collective-permute), with wire-byte factors
                  applied in the roofline layer,

propagating multipliers through the call graph: ``while`` bodies multiply by
``known_trip_count`` (from backend_config), fusions recurse for flops only,
calls/conditionals recurse once.  Unknown trip counts are surfaced in the
result so the analysis is never silently wrong.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "bf16": 2,
    "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ELEMWISE_SKIP = {"parameter", "get-tuple-element", "tuple", "constant",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "iota", "rng-bit-generator"}


def _shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """Total (bytes, elems) over every array shape in a type string."""
    bytes_, elems = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return bytes_, elems


def _last_tuple_element_bytes(type_str: str) -> int:
    """Bytes of the last array in a tuple type (async-start results)."""
    shapes = _SHAPE_RE.findall(type_str)
    if not shapes:
        return 0
    dt, dims = shapes[-1]
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 0)


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    args: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]  # op name -> result type string


_OP_LINE = re.compile(r"^\s+(ROOT\s+)?(%[\w.\-]+)\s+=\s+(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\\"{:n\s]+(\d+)')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=(%[\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
_WINDOW_RE = re.compile(r"window={[^}]*size=([0-9x]+)")


def _parse_rhs(rhs: str) -> Tuple[str, str, List[str], str]:
    """rhs of '=': 'TYPE kind(args), attrs'. Returns (type, kind, args, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str = rhs[:i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.index(" ")
        type_str = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return type_str, rest.split("(")[0], [], ""
    kind = m.group(1)
    depth = 0
    start = rest.index("(")
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    arg_str = rest[start + 1:i]
    attrs = rest[i + 1:]
    args = [a.strip() for a in arg_str.split(",") if a.strip()]
    return type_str, kind, args, attrs


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name = m.group(2)
        type_str, kind, args, attrs = _parse_rhs(m.group(3))
        op = Op(name, kind, type_str, args, attrs)
        cur.ops.append(op)
        cur.shapes[name] = type_str
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # conservative: every top-level op
    hbm_bytes_fused: float = 0.0  # TPU-like: major ops only (see _MAJOR)
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_bytes_fused += other.hbm_bytes_fused * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


# ops that touch HBM on a TPU even under aggressive fusion; pure
# elementwise/layout ops (convert, transpose, broadcast, reshape, compare…)
# fuse into their consumers on TPU and are excluded from the fused model.
_MAJOR = {"dot", "convolution", "fusion", "reduce", "reduce-window",
          "scatter", "gather", "dynamic-slice", "dynamic-update-slice",
          "sort", "custom-call", "copy", "rng-bit-generator", "cholesky",
          "triangular-solve", "select-and-scatter", "pad", "concatenate"}


def _op_flops(op: Op, comp: Computation) -> float:
    kind = op.kind
    res_bytes, res_elems = _shape_bytes_elems(op.type_str)
    if kind == "dot":
        cd = _CDIMS_RE.search(op.attrs)
        lhs_type = comp.shapes.get(op.args[0].split()[-1], "")
        mm = _SHAPE_RE.search(lhs_type)
        k = 1
        if cd and mm and cd.group(1):
            dims = mm.group(2).split(",") if mm.group(2) else []
            for ci in cd.group(1).split(","):
                i = int(ci)
                if i < len(dims):
                    k *= int(dims[i])
        return 2.0 * res_elems * k
    if kind == "convolution":
        w = _WINDOW_RE.search(op.attrs)
        win = 1
        if w:
            for d in w.group(1).split("x"):
                win *= int(d)
        return 2.0 * res_elems * win
    if kind in ("reduce", "reduce-window"):
        opb = 0
        for a in op.args:
            nm = a.split()[-1]
            if nm in comp.shapes:
                _, e = _shape_bytes_elems(comp.shapes[nm])
                opb += e
        return float(opb)
    if kind in _ELEMWISE_SKIP or kind in ("fusion", "while", "call",
                                          "conditional", "custom-call",
                                          "copy", "copy-start", "copy-done"):
        return 0.0
    # generic elementwise / transcendental / compare / select / convert
    return float(res_elems)


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        cache: Dict[str, Cost], in_fusion: bool) -> Cost:
    key = comp.name + ("#f" if in_fusion else "")
    if key in cache:
        return cache[key]
    cost = Cost()
    for op in comp.ops:
        kind = op.kind
        base = kind[:-6] if kind.endswith("-start") else kind
        if base in _COLLECTIVES:
            if kind.endswith("-start"):
                b = _last_tuple_element_bytes(op.type_str)
            elif kind.endswith("-done"):
                b = 0
            else:
                b, _ = _shape_bytes_elems(op.type_str)
            cost.coll_bytes[base] += b
            cost.hbm_bytes += b
            cost.hbm_bytes_fused += b
            continue
        if kind == "while":
            trip = None
            m = _TRIP_RE.search(op.attrs)
            if m:
                trip = int(m.group(1))
            body = _BODY_RE.search(op.attrs)
            cond = _COND_RE.search(op.attrs)
            mult = trip if trip is not None else 1
            if trip is None:
                cost.unknown_trip_loops += 1
            if body and body.group(1) in comps:
                cost.add(analyze_computation(comps[body.group(1)], comps,
                                             cache, in_fusion), mult)
            if cond and cond.group(1) in comps:
                cost.add(analyze_computation(comps[cond.group(1)], comps,
                                             cache, in_fusion), mult)
            continue
        if kind == "fusion":
            callee = _CALLS_RE.search(op.attrs)
            if callee and callee.group(1) in comps:
                sub = analyze_computation(comps[callee.group(1)], comps,
                                          cache, True)
                cost.flops += sub.flops
                for k, v in sub.coll_bytes.items():
                    cost.coll_bytes[k] += v
                cost.unknown_trip_loops += sub.unknown_trip_loops
            if not in_fusion:
                io = _io_bytes(op, comp)
                cost.hbm_bytes += io
                cost.hbm_bytes_fused += io
            continue
        if kind in ("call", "conditional", "async-start", "sort", "map",
                    "scatter", "select-and-scatter", "reduce", "all-reduce"):
            for rx in (_TOAPPLY_RE, _CALLS_RE):
                mm = rx.search(op.attrs)
                if mm and mm.group(1) in comps:
                    callee = comps[mm.group(1)]
                    # comparators/small bodies: flops only
                    sub = analyze_computation(callee, comps, cache, True)
                    cost.flops += sub.flops
            # branch computations for conditional
            for brx in re.findall(r"branch_computations={([^}]*)}", op.attrs):
                for nm in brx.split(","):
                    nm = nm.strip()
                    if nm in comps:
                        cost.add(analyze_computation(comps[nm], comps, cache,
                                                     in_fusion))
        cost.flops += _op_flops(op, comp)
        if not in_fusion and kind not in _ELEMWISE_SKIP:
            io = _io_bytes(op, comp)
            cost.hbm_bytes += io
            if kind in _MAJOR:
                cost.hbm_bytes_fused += io
    cache[key] = cost
    return cost


def _io_bytes(op: Op, comp: Computation) -> float:
    b, _ = _shape_bytes_elems(op.type_str)
    if op.kind.endswith("-start"):
        b = _last_tuple_element_bytes(op.type_str)
    # slicing/gather ops only touch the *sliced* bytes, not the full
    # operand (a scan step dynamic-slicing one layer from the stacked
    # parameters reads one layer, not all of them)
    if op.kind in ("dynamic-slice", "gather", "slice"):
        return float(b)
    if op.kind == "dynamic-update-slice":
        # aliased in place: reads the update operand, writes update-sized
        upd = op.args[1].split()[-1] if len(op.args) > 1 else None
        ub = (_shape_bytes_elems(comp.shapes[upd])[0]
              if upd in comp.shapes else b)
        return float(2 * ub)
    if op.kind == "scatter":
        # scatter(operand, indices, updates): reads indices+updates and
        # read-modify-writes the touched region (~updates-sized)
        extra = 0.0
        for a in op.args[1:]:
            nm = a.split()[-1]
            if nm in comp.shapes:
                extra += _shape_bytes_elems(comp.shapes[nm])[0]
        return float(2.0 * extra)
    for a in op.args:
        nm = a.split()[-1]
        if nm in comp.shapes:
            ab, _ = _shape_bytes_elems(comp.shapes[nm])
            b += ab
    return float(b)


def analyze_hlo(text: str) -> dict:
    """Per-device cost summary of a partitioned, scheduled HLO module."""
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
    cache: Dict[str, Cost] = {}
    if entry is None:
        return {"flops": 0, "hbm_bytes": 0, "collectives": {},
                "unknown_trip_loops": 0}
    cost = analyze_computation(comps[entry], comps, cache, False)
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "hbm_bytes_fused": cost.hbm_bytes_fused,
        "collectives": dict(cost.coll_bytes),
        "unknown_trip_loops": cost.unknown_trip_loops,
    }
