from repro.roofline import hlo_parse  # noqa: F401
