"""Three-term roofline analysis over the dry-run artifacts.

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_wire_bytes / (chips × link_bw)

All HLO quantities come from the partitioned module via
``roofline.hlo_parse`` (per-device numbers × chips = the formulas' global
numerators — the division by chips cancels, so terms are computed from the
per-device values directly).  Wire-byte factors: ring all-reduce moves
≈2× the tensor per device; all-gather/reduce-scatter/all-to-all/permute ≈1×.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS uses 6·N·D (train) or 2·N·D (forward-only), with N = active
params for MoE; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy
waste (remat recompute, causal-chunk waste, dispatch overhead).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops_global(rec: dict) -> float:
    """Analytic MODEL_FLOPS for the whole step (all chips)."""
    from repro.models.config import SHAPES
    cell = SHAPES[rec["shape"]]
    n_active = rec["info"]["active_params"]
    if cell.step == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.step == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def analyze_record(rec: dict) -> Optional[dict]:
    if "skipped" in rec or "error" in rec:
        return None
    hs = rec["hlo_stats"]
    chips = rec["n_devices"]
    flops_dev = hs["flops"]
    # fused byte model (TPU-like) when available, else conservative
    hbm_dev = hs.get("hbm_bytes_fused", hs["hbm_bytes"])
    wire_dev = sum(WIRE_FACTOR.get(k, 1.0) * v
                   for k, v in hs["collectives"].items())

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = wire_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global(rec)
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global else 0.0
    # ideal step time: compute floor, and for serving steps also the
    # unavoidable HBM floor (params + cache must be read once per step)
    t_ideal = (mf / chips) / PEAK_FLOPS
    from repro.models.config import SHAPES
    step_kind = SHAPES[rec["shape"]].step
    if step_kind == "decode":
        floor_bytes = (2.0 * rec["info"]["active_params"]
                       + rec["info"].get("cache_bytes", 0)) / chips
        t_ideal = max(t_ideal, floor_bytes / HBM_BW)
    # roofline fraction: ideal over the dominant term's cost
    t_dom = terms[dominant]
    frac = t_ideal / t_dom if t_dom > 0 else 0.0
    mem = rec["memory_analysis"]
    hbm_per_dev = (mem["argument_bytes"] + mem["output_bytes"]
                   + mem["temp_bytes"] - mem["alias_bytes"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": round(useful, 4),
        "roofline_fraction": round(frac, 4),
        "device_bytes": hbm_per_dev,
        "fits_16gb": hbm_per_dev < 16e9,
        "collectives_dev": hs["collectives"],
        "unknown_trip_loops": hs.get("unknown_trip_loops", 0),
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.35:
            return ("compute-bound with low useful ratio — cut remat "
                    "recompute / causal-chunk waste")
        return "compute-bound near peak — only algorithmic changes help"
    if d == "memory":
        return ("memory-bound — fuse/cast (bf16 cache, wider blocks), "
                "raise arithmetic intensity per HBM byte")
    return ("collective-bound — reshard to cut all-reduce volume, overlap "
            "collectives with compute, or compress cross-pod traffic")


def load_all(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16") -> str:
    """Markdown roofline table (single-pod by default, per the brief)."""
    rows, skipped = [], []
    for rec in load_all(art_dir):
        if rec.get("mesh") != mesh:
            continue
        if "skipped" in rec:
            skipped.append(rec)
            continue
        r = analyze_record(rec)
        if r:
            rows.append(r)
    lines = [
        f"| arch | shape | compute (s) | memory (s) | collective (s) | "
        f"dominant | MODEL/HLO | roofline frac | bytes/dev | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        t = r["terms_s"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['device_bytes'] / 1e9:.2f} GB | "
            f"{'yes' if r['fits_16gb'] else 'NO'} |")
    for s in sorted(skipped, key=lambda x: (x["arch"], x["shape"])):
        lines.append(f"| {s['arch']} | {s['shape']} | — | — | — | skipped | "
                     f"— | — | — | — |")
    return "\n".join(lines)


def pick_hillclimb_cells(art_dir: str = "artifacts/dryrun") -> dict:
    """worst roofline fraction / most collective-bound / most representative."""
    rows = [analyze_record(r) for r in load_all(art_dir)
            if r.get("mesh") == "16x16"]
    rows = [r for r in rows if r]
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    coll = max(rows, key=lambda r: r["terms_s"]["collective"]
               / max(sum(r["terms_s"].values()), 1e-12))
    return {"worst": worst, "collective": coll}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args()
    md = ["# Roofline table — single-pod (16×16 = 256 chips)", "",
          table(args.art, "16x16"), "",
          "# Multi-pod check (2×16×16 = 512 chips)", "",
          table(args.art, "2x16x16"), ""]
    rows = [analyze_record(r) for r in load_all(args.art)
            if r.get("mesh") == "16x16"]
    md.append("## Per-cell bottleneck notes (single-pod)")
    for r in sorted([x for x in rows if x],
                    key=lambda x: (x["arch"], x["shape"])):
        md.append(f"- **{r['arch']} × {r['shape']}** — dominant: "
                  f"{r['dominant']}; {suggestion(r)}")
    with open(args.out, "w") as f:
        f.write("\n".join(md))
    picks = pick_hillclimb_cells(args.art)
    print("worst roofline fraction:", picks["worst"]["arch"],
          picks["worst"]["shape"], picks["worst"]["roofline_fraction"])
    print("most collective-bound:", picks["collective"]["arch"],
          picks["collective"]["shape"], picks["collective"]["terms_s"])
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
