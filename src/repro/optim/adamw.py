"""AdamW with global-norm clipping and warmup+cosine schedule.

Self-contained (no optax dependency).  Moments are fp32 pytrees with the
same partition specs as the parameters (ZeRO-style when FSDP rules are on).
An optional gradient-compression hook (k-means codebook, the paper's engine)
is applied to gradients before the update — see core/grad_compress.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: object
    v: object
    step: jnp.ndarray


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                    jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * factor), tree), norm


def update(grads, state: OptState, params, cfg: AdamWConfig,
           grad_transform: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if grad_transform is not None:
        grads = grad_transform(grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_p = jax.tree.leaves(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (tdef.unflatten(new_p),
            OptState(tdef.unflatten(new_m), tdef.unflatten(new_v), step),
            metrics)
