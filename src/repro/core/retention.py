"""Retention policies: *what* the KV cache must keep, decoupled from
*where* the bytes live.

Every serving-engine component that drops cached positions — the block
pool's sweep, the decode kernels' masks, the engine's host bookkeeping —
used to hardcode the clustered coverage frontier: a ring position is
dead once its claimed absolute position falls below ``cov`` (it has been
absorbed into centroids) or at/after ``t`` (it was never written).  That
welded the whole chunked/paged machinery to all-global-attention
clustered models.

This module names the rule instead.  A :class:`RetentionPolicy` answers
one question — *which claimed positions must survive?* — via a per-slot
lower bound ``retire_lo(slot, t)``: positions in ``[retire_lo, t)`` are
live, positions below it are retired, and positions at/after ``t``
(claimed by the ring layout but never written) are dead unless the
policy sets ``keep_unwritten`` (quota mode reserves storage up front, so
unwritten positions hold blocks that must not be swept).

Four concrete policies:

* :class:`FrontierRetention` — the clustered coverage frontier.  Owns
  the host-side ``cov`` mirror and the frontier-advance formula
  (delegating to :func:`repro.core.kv_compress.coverage_frontier`);
  retire_lo is exactly ``cov``, so sweeps are bit-identical to the old
  ``free_covered``.
* :class:`WindowRetention` — sliding-window (gemma2/3-style local)
  layers: retire_lo is ``t - window``.  The same claimed-position
  safety argument applies: a ring of size >= window never overwrites an
  in-window entry, so retiring ``< t - window`` is loss-free.
* :class:`QuotaRetention` — paged exact-KV with a per-slot block
  budget: nothing is ever retired mid-stream (retire_lo = 0,
  keep_unwritten = True); instead the full depth of a request is
  reserved at admission and returned only at slot exit, so an
  oversubscribed burst defers admissions rather than dying mid-decode.
* :class:`RecurrentRetention` — recurrent-state layers (Mamba2 /
  RG-LRU): a named no-op.  Fixed-size running state has no positions to
  retire; the policy exists so family-driven engine bookkeeping and the
  ``kv_retired_recurrent`` diagnostics stay explicit.

Policies also carry the *write protection* registry that used to be
``free_covered``'s ``exclude=`` parameter: before a sweep, the engine
registers the blocks an imminent ring write will claim so a concurrent
sweep can never free storage the very next launch scatters into.
"""

from __future__ import annotations

import numpy as np

from repro.core import kv_compress


class RetentionPolicy:
    """Which claimed ring positions must survive a write?

    ``retire_lo(slot, t)`` returns the retirement frontier: claimed
    positions ``< retire_lo`` are dead, ``[retire_lo, t)`` are live, and
    ``>= t`` (never written) are dead unless ``keep_unwritten``.
    """

    kind = "base"
    #: True when positions claimed but not yet written still hold
    #: storage that must survive a sweep (quota reservations).
    keep_unwritten = False

    def retire_lo(self, slot: int, t: int) -> int:
        raise NotImplementedError

    # -- write protection (absorbs free_covered's old ``exclude=``) ----
    def protect_write(self, slot: int, blocks) -> None:
        """Register block indices an imminent write will touch."""
        self._protected()[slot] = frozenset(int(b) for b in blocks)

    def clear_protection(self, slot: int) -> None:
        self._protected().pop(slot, None)

    def protected_blocks(self, slot: int) -> frozenset:
        return self._protected().get(slot, frozenset())

    def _protected(self) -> dict:
        d = getattr(self, "_prot", None)
        if d is None:
            d = self._prot = {}
        return d

    def on_slot_free(self, slot: int) -> None:
        """Reset per-slot policy state when the engine recycles a slot."""
        self.clear_protection(slot)


class FrontierRetention(RetentionPolicy):
    """Today's clustered coverage frontier, bit-identical.

    Owns the host mirror of the per-slot ``cov`` device array (the
    engine used to keep a bare ``cov_h`` numpy array) and the frontier
    formula: positions below ``cov`` were absorbed into k-medians
    centroids, so dropping their exact bytes is loss-free by
    construction.  All frontier targets (admission, streaming absorb,
    compaction) come from :func:`kv_compress.coverage_frontier`.
    """

    kind = "frontier"

    def __init__(self, n_slots: int, ccfg: "kv_compress.KVCompressConfig"):
        self.ccfg = ccfg
        self.cov = np.zeros(n_slots, np.int32)

    def retire_lo(self, slot: int, t: int) -> int:
        return int(self.cov[slot])

    def frontier(self, slot: int) -> int:
        return int(self.cov[slot])

    def set_frontier(self, slot: int, cov: int) -> None:
        self.cov[slot] = int(cov)

    def target(self, pos: int) -> int:
        """Loss-free frontier for a stream at absolute length ``pos``."""
        return kv_compress.coverage_frontier(int(pos), self.ccfg)

    def on_slot_free(self, slot: int) -> None:
        super().on_slot_free(slot)
        self.cov[slot] = 0


class WindowRetention(RetentionPolicy):
    """Sliding-window local attention: keep the last ``window`` positions.

    A local layer at stream length ``t`` attends positions
    ``[max(0, t - window), t)`` only, so anything older is dead by the
    model's own mask — the ring analogue of the coverage frontier, with
    the window edge instead of ``cov``.  ``advance(slot, t)`` tracks the
    per-slot stream head and returns how many positions newly crossed
    the window edge (the ``kv_retired_window`` counter); the count is in
    positions, not blocks, because local rings are dense (never
    pool-backed) — retirement is virtual until the ring slot is
    overwritten.
    """

    kind = "window"

    def __init__(self, window: int, n_slots: int = 0):
        if window <= 0:
            raise ValueError("WindowRetention needs a positive window")
        self.window = int(window)
        self._head = np.zeros(n_slots, np.int64)

    def retire_lo(self, slot: int, t: int) -> int:
        return max(0, int(t) - self.window)

    def advance(self, slot: int, t: int) -> int:
        """Move slot's stream head to ``t``; return newly retired count."""
        old = int(self._head[slot])
        t = max(old, int(t))
        self._head[slot] = t
        return max(0, t - self.window) - max(0, old - self.window)

    def on_slot_free(self, slot: int) -> None:
        super().on_slot_free(slot)
        if slot < self._head.shape[0]:
            self._head[slot] = 0


class RecurrentRetention(RetentionPolicy):
    """Recurrent-state layers (Mamba2 'M', RG-LRU 'R'): nothing retires.

    The recurrent family (see :mod:`repro.core.layer_state`) carries a
    fixed-size running state per slot instead of a position-indexed
    ring: every past token is already folded into ``(conv, ssm)`` /
    ``(conv, h)``, so there are no claimed positions to retire, protect,
    or sweep — the policy is a named no-op.  It exists so the engine's
    family-driven bookkeeping stays uniform: the per-serve retirement
    counters carry an explicit ``kv_retired_recurrent = 0`` entry (the
    invariant, not an omission), and diagnostics name the family instead
    of silently skipping it.
    """

    kind = "recurrent"
    #: nothing is position-claimed, so sweeps must not touch these slots
    keep_unwritten = True

    def __init__(self, kinds=("M", "R")):
        self.kinds = tuple(kinds)

    def retire_lo(self, slot: int, t: int) -> int:
        return 0

    def advance(self, slot: int, t: int) -> int:
        """Stream-head bookkeeping analogue of WindowRetention.advance:
        recurrent state folds every position, so zero positions retire."""
        return 0

    def diagnostics(self) -> dict:
        """Named per-family counters for the end-of-serve publish."""
        return {"kv_retired_recurrent": 0,
                "retention_recurrent_kinds": "".join(self.kinds)}


class QuotaRetention(RetentionPolicy):
    """Paged exact-KV with a per-slot block budget.

    Exact caches have no loss-free retirement rule mid-stream — every
    written position may be attended until the request exits — so
    nothing retires (``retire_lo = 0``) and reserved-but-unwritten
    positions keep their blocks (``keep_unwritten``).  The eviction
    story moves to admission: ``admit_blocks`` computes the full block
    depth a request will ever claim, the engine reserves it before
    feeding the first token, and an admission that can't reserve defers
    back to the queue instead of hitting ``PoolExhausted`` mid-decode.
    Blocks return to the pool only at slot exit.
    """

    kind = "quota"
    keep_unwritten = True

    def __init__(self, block_size: int, blocks_per_slot: int):
        self.block_size = int(block_size)
        self.blocks_per_slot = int(blocks_per_slot)

    def retire_lo(self, slot: int, t: int) -> int:
        return 0

    def admit_blocks(self, plen: int, max_new: int) -> int:
        """Blocks needed for a request's full written depth.

        Positions written over the request's life are ``0..plen-1``
        (prompt) plus ``max_new - 1`` generated tokens (the final
        sampled token is never written back), so the claim depth is
        ``plen + max(1, max_new) - 1`` positions, rounded up to blocks
        and clamped to the per-slot budget.
        """
        depth = int(plen) + max(1, int(max_new)) - 1
        need = -(-depth // self.block_size)
        return min(self.blocks_per_slot, max(1, need))
