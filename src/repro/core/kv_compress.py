"""KV-cache memory management via bit-serial k-medians clustering.

Long-context decode is HBM-bound on the KV cache.  This module compresses
a (S, H, Dh) cache to C centroids per head by clustering the *keys* with
the paper's bit-serial k-medians engine (median centroids resist the
outlier keys that attention sinks produce); values are combined per
cluster with softmax-aware averaging, and attention runs over centroids
with a ``log(count)`` bias so a centroid representing m keys receives the
mass of m keys (clustered-attention estimator).

Memory: S → C per layer-head (e.g. 32768 → 512 is 64×) with the quality
measured in benchmarks/bench_kv_compress.py against exact attention.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitserial, clustering
from repro.core.clustering import ClusterConfig


@dataclasses.dataclass(frozen=True)
class KVCompressConfig:
    n_clusters: int = 256
    iters: int = 6
    metric: str = "l2"        # assignment metric for keys
    bits: int = 16            # fixed-point width for median centroids
    keep_recent: int = 128    # exact tail (recency window kept uncompressed)


class CompressedKV(NamedTuple):
    k_cents: jnp.ndarray      # (H, C, Dh) key centroids (bit-serial medians)
    v_cents: jnp.ndarray      # (H, C, Dh) mean value per cluster
    counts: jnp.ndarray       # (H, C)
    k_tail: jnp.ndarray       # (H, R, Dh) exact recent keys
    v_tail: jnp.ndarray       # (H, R, Dh)


def compress_head(keys, values, cfg: KVCompressConfig, seed: int = 0):
    """keys/values (S, Dh) → centroids for one head."""
    ccfg = ClusterConfig(k=cfg.n_clusters, metric=cfg.metric,
                         centroid="median", max_iters=cfg.iters,
                         bits=cfg.bits, init="kmeanspp", seed=seed)
    res = clustering.fit(keys.astype(jnp.float32), ccfg, use_kernel=False)
    onehot = jax.nn.one_hot(res.assign, cfg.n_clusters, dtype=jnp.float32)
    vsum = onehot.T @ values.astype(jnp.float32)
    counts = onehot.sum(0)
    v_cents = vsum / jnp.maximum(counts, 1.0)[:, None]
    return res.centroids, v_cents, counts


def compress_cache(k_cache, v_cache, cfg: KVCompressConfig):
    """k/v (S, H, Dh) → CompressedKV.  The most recent ``keep_recent``
    positions stay exact (recency matters most for LM attention)."""
    s, h, dh = k_cache.shape
    r = min(cfg.keep_recent, s)
    head = s - r
    k_old = k_cache[:head].transpose(1, 0, 2)            # (H, S', Dh)
    v_old = v_cache[:head].transpose(1, 0, 2)

    k_cents, v_cents, counts = jax.vmap(
        lambda kk, vv: compress_head_jit(kk, vv, cfg))(k_old, v_old)
    return CompressedKV(
        k_cents=k_cents, v_cents=v_cents, counts=counts,
        k_tail=k_cache[head:].transpose(1, 0, 2),
        v_tail=v_cache[head:].transpose(1, 0, 2))


@partial(jax.jit, static_argnames=("cfg",))
def compress_head_jit(keys, values, cfg: KVCompressConfig):
    return compress_head(keys, values, cfg)


def clustered_attention(q, ckv: CompressedKV, *, scale: float):
    """q (H, Dh) → out (H, Dh) using centroid attention with count bias.

    softmax over [centroids ⊕ exact tail]; centroid c with m keys gets a
    +log(m) logit bias (it stands for m identical-score keys).
    """
    qf = q.astype(jnp.float32)
    s_c = jnp.einsum("hd,hcd->hc", qf, ckv.k_cents.astype(jnp.float32))
    s_c = s_c * scale + jnp.log(jnp.maximum(ckv.counts, 1e-9))
    s_c = jnp.where(ckv.counts > 0, s_c, -1e30)
    s_t = jnp.einsum("hd,hrd->hr", qf,
                     ckv.k_tail.astype(jnp.float32)) * scale
    s = jnp.concatenate([s_c, s_t], axis=1)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    v_all = jnp.concatenate([ckv.v_cents.astype(jnp.float32),
                             ckv.v_tail.astype(jnp.float32)], axis=1)
    return jnp.einsum("hc,hcd->hd", p, v_all).astype(q.dtype)


def exact_attention(q, k_cache, v_cache, *, scale: float):
    """Oracle for quality evaluation: q (H, Dh), caches (S, H, Dh)."""
    qf = q.astype(jnp.float32)
    s = jnp.einsum("hd,shd->hs", qf, k_cache.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hs,shd->hd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def memory_ratio(s: int, cfg: KVCompressConfig) -> float:
    return s / float(cfg.n_clusters + cfg.keep_recent)
