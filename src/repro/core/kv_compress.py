"""KV-cache memory management via bit-serial k-medians clustering.

Long-context decode is HBM-bound on the KV cache.  This module compresses
a (S, H, Dh) cache to C centroids per head by clustering the *keys* with
the paper's bit-serial k-medians engine (median centroids resist the
outlier keys that attention sinks produce); values are combined per
cluster with softmax-aware averaging, and attention runs over centroids
with a ``log(count)`` bias so a centroid representing m keys receives the
mass of m keys (clustered-attention estimator).

Memory: S → C per layer-head (e.g. 32768 → 512 is 64×) with the quality
measured in benchmarks/bench_kv_compress.py against exact attention.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitserial, clustering
from repro.core.clustering import ClusterConfig


@dataclasses.dataclass(frozen=True)
class KVCompressConfig:
    n_clusters: int = 256
    iters: int = 6
    metric: str = "l2"        # assignment metric for keys
    bits: int = 16            # fixed-point width for median centroids
    keep_recent: int = 128    # exact tail (recency window kept uncompressed)
    refresh_every: int = 0    # serving: decode steps between compactions
                              # (0 = one-shot compaction, full exact tail);
                              # effectively clamped to keep_recent.  The
                              # centroid coverage frontier advances to
                              # t - keep_recent + refresh_every so every ring
                              # entry is folded into centroids before the
                              # next refresh_every decode steps evict it.
    prompt_clusters: int = 0  # chunked admission: centroid budget while a
                              # prompt streams in (absorb_chunk touches only
                              # the first ``prompt_clusters`` rows; 0 = the
                              # full n_clusters budget).  Keeps prompt-time
                              # Lloyd cheap; the first regular compaction
                              # after admission spreads mass over all rows.

    @property
    def refresh(self) -> int:
        return min(self.refresh_every, self.keep_recent)

    @property
    def prompt_budget(self) -> int:
        return self.prompt_clusters or self.n_clusters


def coverage_frontier(pos: int, cfg: KVCompressConfig) -> int:
    """Loss-free coverage frontier for a stream at absolute length ``pos``.

    Positions below the frontier are absorbed into centroids; the exact
    tail ring keeps ``[frontier, pos)``, which fits in ``keep_recent``
    slots with ``refresh`` steps of headroom before the next compaction
    must run.  Every frontier target the serving engine uses (admission,
    streaming absorb, compaction) is this one formula — the
    ``FrontierRetention`` policy delegates here so the retirement rule
    and the k-medians coverage can never drift apart.
    """
    pos = int(pos)
    return max(0, min(pos, pos - cfg.keep_recent + cfg.refresh))


class CompressedKV(NamedTuple):
    k_cents: jnp.ndarray      # (H, C, Dh) key centroids (bit-serial medians)
    v_cents: jnp.ndarray      # (H, C, Dh) mean value per cluster
    counts: jnp.ndarray       # (H, C)
    k_tail: jnp.ndarray       # (H, R, Dh) exact recent keys
    v_tail: jnp.ndarray       # (H, R, Dh)


def compress_head(keys, values, cfg: KVCompressConfig, seed: int = 0,
                  weights=None, init_centroids=None, axis_name=None):
    """keys/values (S, Dh) → centroids for one head.

    ``weights`` (S,) ≥ 0 mask padded positions (weight 0) or carry counts of
    pre-aggregated summaries; ``init_centroids`` warm-starts Lloyd for
    incremental re-compaction between decode bursts.

    ``axis_name``: when the point rows span a mesh axis under ``shard_map``
    (e.g. the (C ⊕ R) recompaction points of a centroid bank sharded over
    the model axis), the weighted bit-serial k-medians psum-merges per-bit
    vote counts — and the value sums / counts here psum the same way — so
    every shard converges on identical centroids (the paper's reduction
    tree).  Distributed fits require ``init_centroids`` (replicated)."""
    ccfg = ClusterConfig(k=cfg.n_clusters, metric=cfg.metric,
                         centroid="median", max_iters=cfg.iters,
                         bits=cfg.bits, init="kmeanspp", seed=seed)
    res = clustering.fit(keys.astype(jnp.float32), ccfg, init_centroids,
                         use_kernel=False, weights=weights,
                         axis_name=axis_name)
    onehot = jax.nn.one_hot(res.assign, cfg.n_clusters, dtype=jnp.float32)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None]
    vsum = onehot.T @ values.astype(jnp.float32)
    counts = onehot.sum(0)
    if axis_name is not None:
        vsum = jax.lax.psum(vsum, axis_name)
        counts = jax.lax.psum(counts, axis_name)
    v_cents = vsum / jnp.maximum(counts, 1.0)[:, None]
    return res.centroids, v_cents, counts


def compress_cache(k_cache, v_cache, cfg: KVCompressConfig):
    """k/v (S, H, Dh) → CompressedKV.  The most recent ``keep_recent``
    positions stay exact (recency matters most for LM attention)."""
    s, h, dh = k_cache.shape
    r = min(cfg.keep_recent, s)
    head = s - r
    k_old = k_cache[:head].transpose(1, 0, 2)            # (H, S', Dh)
    v_old = v_cache[:head].transpose(1, 0, 2)

    k_cents, v_cents, counts = jax.vmap(
        lambda kk, vv: compress_head_jit(kk, vv, cfg))(k_old, v_old)
    return CompressedKV(
        k_cents=k_cents, v_cents=v_cents, counts=counts,
        k_tail=k_cache[head:].transpose(1, 0, 2),
        v_tail=v_cache[head:].transpose(1, 0, 2))


@partial(jax.jit, static_argnames=("cfg",))
def compress_head_jit(keys, values, cfg: KVCompressConfig):
    return compress_head(keys, values, cfg)


# ---------------------------------------------------------------------------
# Batched, device-resident compaction (serving path)
#
# Cache-layout leaves: k/v_cents (B, C, H, Dh), counts (B, C, H),
# k/v_tail (B, R, H, Dh) in ring order (position p at slot p % R), and
# cov (B,) int32 — centroids summarize positions [0, cov); the tail is
# exact for [cov, t).  Masking the tail at pos >= cov removes the seed's
# double-count/data-loss ambiguity at the ring-eviction boundary: every
# position is represented exactly once, and a position is only ever
# evicted from the ring after a compaction has folded it into centroids
# (guaranteed by refresh_every <= keep_recent).
# ---------------------------------------------------------------------------


def ring_positions(r: int, t):
    """Absolute position held by each of the r ring slots at time t
    (next write goes to slot t % r).  t scalar or (B,) → (..., r).
    Canonical ring math — models/attention.ring_slot_positions delegates
    here so compaction coverage and the attention mask can't drift."""
    s = jnp.arange(r)
    tb = jnp.asarray(t)[..., None]
    wrapped = tb - r + jnp.mod(s - tb, r)
    return jnp.where(tb <= r, jnp.broadcast_to(s, wrapped.shape), wrapped)


def _tail_ring_slice(kb, vb, lb, r: int):
    """Last r positions of one slot's chronological cache, laid out in ring
    order.  kb/vb (S, H, Dh), lb scalar valid length."""
    start = jnp.maximum(lb - r, 0)
    tk = jax.lax.dynamic_slice_in_dim(kb, start, r, 0)   # chrono (r, H, Dh)
    tv = jax.lax.dynamic_slice_in_dim(vb, start, r, 0)
    slots = jnp.mod(start + jnp.arange(r), r)
    return (jnp.zeros_like(tk).at[slots].set(tk),
            jnp.zeros_like(tv).at[slots].set(tv))


@partial(jax.jit, static_argnames=("cfg",))
def compress_cache_batched(k, v, lengths, cfg: KVCompressConfig):
    """Exact slot caches → clustered layout, one jitted call.

    k/v (B, S, H, Dh) chronological slot buffers, lengths (B,) valid
    counts.  vmap over batch ⊕ head — no Python loops, one trace.  Padded
    positions are excluded via point weights, so ragged slots batch
    cleanly (the MapReduce-style "cluster many independent streams at
    once" regime)."""
    b, s, h, dh = k.shape
    r = min(cfg.keep_recent, s)
    cov = jnp.clip(lengths - r + cfg.refresh, 0, lengths)
    pos = jnp.arange(s)
    w = (pos[None, :] < cov[:, None]).astype(jnp.float32)      # (B, S)

    kT = k.transpose(0, 2, 1, 3).astype(jnp.float32)           # (B, H, S, Dh)
    vT = v.transpose(0, 2, 1, 3).astype(jnp.float32)

    def one_slot(kb, vb, wb):
        return jax.vmap(
            lambda kk, vv: compress_head(kk, vv, cfg, weights=wb))(kb, vb)

    k_cents, v_cents, counts = jax.vmap(one_slot)(kT, vT, w)
    k_tail, v_tail = jax.vmap(
        lambda kb, vb, lb: _tail_ring_slice(kb, vb, lb, r))(k, v, lengths)
    return {
        "k_cents": k_cents.transpose(0, 2, 1, 3).astype(k.dtype),
        "v_cents": v_cents.transpose(0, 2, 1, 3).astype(v.dtype),
        "counts": counts.transpose(0, 2, 1),                   # (B, C, H)
        "k_tail": k_tail.astype(k.dtype),
        "v_tail": v_tail.astype(v.dtype),
        "cov": cov.astype(jnp.int32),
    }


@partial(jax.jit, static_argnames=("cfg", "axis_name"))
def recompact_clustered(cache, lengths, cfg: KVCompressConfig,
                        axis_name=None):
    """Incremental re-compaction of an already-clustered cache.

    The points to recluster are the old centroids (weighted by their
    counts — each is a pre-aggregated summary) plus the ring entries that
    have aged past the new coverage frontier.  Warm-started from the old
    centroids, so between decode bursts Lloyd only has to absorb the ≤
    refresh_every new keys — the streaming-clustering update.

    ``axis_name`` makes the k-medians psum-consistent when the point rows
    are sharded across a mesh axis under shard_map (the warm-started
    centroids satisfy the distributed-init requirement).

    Slots whose frontier does not advance (``new_cov == cov``: drained
    slots, admitting slots passed length 0, slots compacted again before
    new tokens aged past the frontier) keep their centroid bank
    BIT-IDENTICAL — re-running Lloyd over the old centroids with zero new
    mass is not a bitwise no-op (duplicate centroids merge under
    lowest-index tie-breaking), so without the gate a compaction
    triggered by one slot would perturb every other slot's summaries,
    making per-slot state depend on *when* neighbours forced a pass.
    Per-slot determinism is what lets the engine compact slots on their
    own cadence and admit prefix-shared requests on a different schedule
    without changing anyone's tokens."""
    k_cents = cache["k_cents"].astype(jnp.float32)     # (B, C, H, Dh)
    v_cents = cache["v_cents"].astype(jnp.float32)
    counts = cache["counts"]                           # (B, C, H)
    k_tail = cache["k_tail"].astype(jnp.float32)       # (B, R, H, Dh)
    v_tail = cache["v_tail"].astype(jnp.float32)
    cov = cache["cov"]                                 # (B,)
    b, c, h, dh = k_cents.shape
    r = k_tail.shape[1]
    lengths = jnp.asarray(lengths)
    # frontier is monotone even for drained slots (engine passes length 0
    # for finished slots; their cov must not regress and re-admit tail
    # entries already folded into centroids)
    new_cov = jnp.maximum(cov, jnp.clip(lengths - r + cfg.refresh,
                                        0, lengths))

    ring_pos = ring_positions(r, lengths)              # (B, R)
    w_tail = ((ring_pos >= cov[:, None])
              & (ring_pos < new_cov[:, None])).astype(jnp.float32)

    def one_head(kc, vc, cnt, kt, vt, wt):
        x = jnp.concatenate([kc, kt], axis=0)          # (C + R, Dh)
        vals = jnp.concatenate([vc, vt], axis=0)
        wgt = jnp.concatenate([cnt, wt], axis=0)
        return compress_head(x, vals, cfg, weights=wgt, init_centroids=kc,
                             axis_name=axis_name)

    def one_slot(kc, vc, cnt, kt, vt, wt):
        return jax.vmap(lambda *a: one_head(*a, wt))(
            kc.transpose(1, 0, 2), vc.transpose(1, 0, 2), cnt.T,
            kt.transpose(1, 0, 2), vt.transpose(1, 0, 2))

    nk, nv, ncnt = jax.vmap(one_slot)(k_cents, v_cents, counts,
                                      k_tail, v_tail, w_tail)
    changed = (new_cov > cov)[:, None, None]
    return dict(
        cache,
        k_cents=jnp.where(changed[..., None], nk.transpose(0, 2, 1, 3),
                          k_cents).astype(cache["k_cents"].dtype),
        v_cents=jnp.where(changed[..., None], nv.transpose(0, 2, 1, 3),
                          v_cents).astype(cache["v_cents"].dtype),
        counts=jnp.where(changed, ncnt.transpose(0, 2, 1), counts),
        cov=new_cov.astype(jnp.int32),
    )


@partial(jax.jit, static_argnames=("cfg",))
def absorb_chunk(cache, lengths, target_cov, cfg: KVCompressConfig):
    """Streaming admission-time compaction: advance a slot's coverage
    frontier to ``target_cov`` by folding the ring entries aged past it
    into centroids — the one-pass stream-clustering update that lets a
    prompt longer than the tail ring be admitted chunk by chunk without
    ever materializing its exact KV.

    Differences from ``recompact_clustered`` (the between-decode-bursts
    refresh):

      * the frontier target is caller-chosen (the engine asks for exactly
        enough coverage that the next prompt chunk can overwrite ring
        slots safely), not derived from ``refresh_every``;
      * only the first ``cfg.prompt_budget`` centroid rows are written —
        the per-request prompt-time centroid budget.  All rows still
        participate as weighted points, so any mass outside the budget is
        migrated in, never dropped (total counts == new_cov per head);
      * dead centroid rows are deterministically re-seeded by farthest-
        point selection (clustering.seed_empty_centroids) before the
        warm-started weighted k-medians — the first absorbed chunk of a
        request starts from an all-zero bank.

    cache: clustered slot leaves (B, ...); lengths (B,) ring positions
    written so far; target_cov (B,) desired frontier (clipped to
    [cov, lengths]).  Slots with target_cov <= cov keep centroid rows
    bit-identical (their ring contributes zero weight and the warm start
    is only reseeded where counts are zero).
    """
    budget = cfg.prompt_budget
    k_cents = cache["k_cents"].astype(jnp.float32)     # (B, C, H, Dh)
    v_cents = cache["v_cents"].astype(jnp.float32)
    counts = cache["counts"]                           # (B, C, H)
    k_tail = cache["k_tail"].astype(jnp.float32)       # (B, R, H, Dh)
    v_tail = cache["v_tail"].astype(jnp.float32)
    cov = cache["cov"]                                 # (B,)
    b, c, h, dh = k_cents.shape
    r = k_tail.shape[1]
    lengths = jnp.asarray(lengths)
    new_cov = jnp.clip(jnp.maximum(cov, jnp.asarray(target_cov)), 0, lengths)

    ring_pos = ring_positions(r, lengths)              # (B, R)
    w_tail = ((ring_pos >= cov[:, None])
              & (ring_pos < new_cov[:, None])).astype(jnp.float32)
    bcfg = dataclasses.replace(cfg, n_clusters=budget)

    def one_head(kc, vc, cnt, kt, vt, wt, fresh):
        x = jnp.concatenate([kc, kt], axis=0)          # (C + R, Dh)
        vals = jnp.concatenate([vc, vt], axis=0)
        wgt = jnp.concatenate([cnt, wt], axis=0)
        init = clustering.seed_empty_centroids(
            x, kc[:budget], cnt[:budget] > 0, cfg.metric,
            weights=wgt * fresh)
        nk, nv, ncnt = compress_head(x, vals, bcfg, weights=wgt,
                                     init_centroids=init)
        return (kc.at[:budget].set(nk), vc.at[:budget].set(nv),
                jnp.concatenate([ncnt, jnp.zeros((c - budget,),
                                                 ncnt.dtype)]))

    def one_slot(kc, vc, cnt, kt, vt, wt, fresh):
        return jax.vmap(lambda *a: one_head(*a, wt, fresh))(
            kc.transpose(1, 0, 2), vc.transpose(1, 0, 2), cnt.T,
            kt.transpose(1, 0, 2), vt.transpose(1, 0, 2))

    # fresh gates the seeding pool so unchanged slots can't be perturbed
    # even by reseeding a zero-count row onto a live point
    fresh = (new_cov > cov).astype(jnp.float32)
    nk, nv, ncnt = jax.vmap(one_slot)(k_cents, v_cents, counts,
                                      k_tail, v_tail, w_tail, fresh)
    changed = (new_cov > cov)[:, None, None]
    out_counts = jnp.where(changed, ncnt.transpose(0, 2, 1), counts)
    return dict(
        cache,
        k_cents=jnp.where(changed[..., None], nk.transpose(0, 2, 1, 3),
                          cache["k_cents"].astype(jnp.float32)
                          ).astype(cache["k_cents"].dtype),
        v_cents=jnp.where(changed[..., None], nv.transpose(0, 2, 1, 3),
                          cache["v_cents"].astype(jnp.float32)
                          ).astype(cache["v_cents"].dtype),
        counts=out_counts,
        cov=new_cov.astype(jnp.int32),
    )


def clustered_attention(q, ckv: CompressedKV, *, scale: float):
    """q (H, Dh) → out (H, Dh) using centroid attention with count bias.

    softmax over [centroids ⊕ exact tail]; centroid c with m keys gets a
    +log(m) logit bias (it stands for m identical-score keys).
    """
    qf = q.astype(jnp.float32)
    s_c = jnp.einsum("hd,hcd->hc", qf, ckv.k_cents.astype(jnp.float32))
    s_c = s_c * scale + jnp.log(jnp.maximum(ckv.counts, 1e-9))
    s_c = jnp.where(ckv.counts > 0, s_c, -1e30)
    s_t = jnp.einsum("hd,hrd->hr", qf,
                     ckv.k_tail.astype(jnp.float32)) * scale
    s = jnp.concatenate([s_c, s_t], axis=1)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    v_all = jnp.concatenate([ckv.v_cents.astype(jnp.float32),
                             ckv.v_tail.astype(jnp.float32)], axis=1)
    return jnp.einsum("hc,hcd->hd", p, v_all).astype(q.dtype)


def exact_attention(q, k_cache, v_cache, *, scale: float):
    """Oracle for quality evaluation: q (H, Dh), caches (S, H, Dh)."""
    qf = q.astype(jnp.float32)
    s = jnp.einsum("hd,shd->hs", qf, k_cache.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hs,shd->hd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def memory_ratio(s: int, cfg: KVCompressConfig) -> float:
    return s / float(cfg.n_clusters + cfg.keep_recent)
