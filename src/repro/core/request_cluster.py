"""Request processing: clustering-based batch formation for serving.

Static batching pads every request in a batch to the longest prompt in it;
with mixed lengths the padding waste dominates.  This module clusters the
queued requests by (prompt_len, expected_new_tokens) features using the
paper's bit-serial k-medians (medians — not means — because request-length
distributions are heavy-tailed, the paper's exact motivation) and forms
batches within clusters, minimizing padded-token waste.

``plan_batches`` is the scheduler entry; ``padding_waste`` the metric the
benchmark compares against FIFO batching (paper-table analogue).
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering
from repro.core.clustering import ClusterConfig


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    # SLO class (runtime/scheduler.py): larger = more important.  0 is
    # best-effort/batch; priorities only matter to a Server configured
    # with ServerConfig.scheduler — everything else ignores them, and a
    # request's greedy tokens never depend on its priority (scheduling
    # is schedule-invisible by construction).
    priority: int = 0
    # soft TTFT deadline in ms from serve() start (0 = none).  A
    # best-effort request whose deadline has already passed when the
    # engine would otherwise defer it under pool pressure is shed
    # instead of retried — it can no longer meet its SLO, so its blocks
    # are better spent on requests that still can.
    deadline_ms: float = 0.0


class BatchPlan(NamedTuple):
    batches: List[List[int]]      # request uids per batch
    waste: float                  # padded-token fraction


def features(reqs: Sequence[Request]) -> np.ndarray:
    return np.array([[r.prompt_len, r.max_new_tokens] for r in reqs],
                    np.float32)


def plan_batches(reqs: Sequence[Request], batch_size: int,
                 n_clusters: int = 4, seed: int = 0) -> BatchPlan:
    """Cluster by (len, gen) with bit-serial k-medians, then fill batches
    cluster-by-cluster in sorted-length order.

    Priority-aware: when the queue mixes SLO classes, each class is
    planned independently (highest first) and the class plans
    concatenate, so every high-priority request is admitted before any
    lower-priority one — the padding-minimal clustering runs within a
    class, never across classes (a batch straddling classes would make a
    high-priority TTFT wait on best-effort prompts).  Single-class
    queues (the default: every ``priority`` 0) take the exact pre-SLO
    path, bit-identical plans included."""
    if not reqs:
        return BatchPlan([], 0.0)
    prios = sorted({r.priority for r in reqs}, reverse=True)
    if len(prios) > 1:
        by_uid = {r.uid: r for r in reqs}
        batches: List[List[int]] = []
        for p in prios:
            sub = [r for r in reqs if r.priority == p]
            batches.extend(plan_batches(sub, batch_size, n_clusters,
                                        seed).batches)
        waste = padding_waste([[by_uid[u] for u in b] for b in batches])
        return BatchPlan(batches, waste)
    x = features(reqs)
    if len(reqs) < max(4 * batch_size, n_clusters * batch_size):
        # small queue (clusters could not each fill a batch on average):
        # a global length sort is optimal; clustering pays off on large
        # queues where the 2-D (len, gen) structure matters
        order = np.argsort(x[:, 0], kind="stable").tolist()
        batches = [order[i:i + batch_size]
                   for i in range(0, len(order), batch_size)]
        waste = padding_waste([[reqs[i] for i in b] for b in batches])
        return BatchPlan([[reqs[i].uid for i in b] for b in batches], waste)
    k = min(n_clusters, len(reqs))
    cfg = ClusterConfig(k=k, metric="l1", centroid="median", max_iters=10,
                        bits=16, seed=seed)
    res = clustering.fit(jnp.asarray(x), cfg, use_kernel=False)
    assign = np.asarray(res.assign)

    # inside a cluster sort by length; order clusters by median prompt length
    # so any spill between adjacent clusters pairs similar lengths
    clusters = []
    for c in range(k):
        idx = np.where(assign == c)[0]
        if len(idx) == 0:
            continue
        clusters.append(idx[np.argsort(x[idx, 0], kind="stable")])
    clusters.sort(key=lambda idx: float(np.median(x[idx, 0])))

    # fill full batches strictly within each cluster; cluster remainders are
    # merged across clusters in length order, so a mixed batch only ever
    # combines adjacent-length leftovers instead of straddling modes
    batches: List[List[int]] = []
    leftover: List[int] = []
    for idx in clusters:
        n_full = (len(idx) // batch_size) * batch_size
        batches.extend(idx[i:i + batch_size].tolist()
                       for i in range(0, n_full, batch_size))
        leftover.extend(idx[n_full:].tolist())
    leftover.sort(key=lambda i: (x[i, 0], i))
    batches.extend(leftover[i:i + batch_size]
                   for i in range(0, len(leftover), batch_size))
    waste = padding_waste([[reqs[i] for i in b] for b in batches])
    return BatchPlan([[reqs[i].uid for i in b] for b in batches], waste)


def plan_fifo(reqs: Sequence[Request], batch_size: int) -> BatchPlan:
    batches = [list(range(len(reqs)))[i:i + batch_size]
               for i in range(0, len(reqs), batch_size)]
    waste = padding_waste([[reqs[i] for i in b] for b in batches])
    return BatchPlan([[reqs[i].uid for i in b] for b in batches], waste)


def padding_waste(batches: List[List[Request]]) -> float:
    """Fraction of padded prompt tokens across all batches."""
    padded, useful = 0, 0
    for b in batches:
        if not b:
            continue
        mx = max(r.prompt_len for r in b)
        for r in b:
            useful += r.prompt_len
            padded += mx - r.prompt_len
    return padded / max(padded + useful, 1)
