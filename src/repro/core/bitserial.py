"""Bit-serial median via majority voting — the paper's core algorithm.

MSB→LSB scan.  At every bit position the *majority vote* across all (still
active) inputs yields the median's bit; inputs whose bit disagrees with the
majority are retired, and their remaining bits are replaced by their deviating
bit (the paper's "minority bits ... replace all of the bits on their
right-hand side"), so retired inputs keep voting on the correct side.

Majority tie-break follows the paper exactly: "the output is 0 when (N/2) or
more inputs are 0" ⇒ a bit is 1 iff strictly more than half of the effective
votes are 1 ⇒ for even N the scan converges to the *lower* median (pinned by
tests against a sort oracle).

The paper's P/I inclusion predicates become first-class ``weights`` (0/1 masks
or positive integer counts); the inter-array reduction tree becomes a
per-bit ``psum`` over ``axis_name`` when running under ``shard_map``.

All entry points are pure and jit/vmap/shard_map friendly.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantizer


def _shift_right(u, b):
    return jax.lax.shift_right_logical(u, b.astype(u.dtype))


def _bit_at(u, b):
    """Bit b (traced int32 scalar) of uint32 array u, as float32 0/1."""
    return (_shift_right(u, b) & jnp.uint32(1)).astype(jnp.float32)


def _set_bit(med, mbit_bool, b):
    one = jax.lax.shift_left(jnp.uint32(1), b.astype(jnp.uint32))
    return jnp.where(mbit_bool, med | one, med)


def median_bits(u, *, weights=None, bits: int = 32, axis_name: Optional[str] = None):
    """Weighted bit-serial median of unsigned-ordered ints along axis 0.

    u: uint32 (N, ...).  weights: optional (N, ...) broadcastable, >= 0.
    Returns uint32 median with the leading axis reduced.  When ``axis_name``
    is given the vote counts are ``psum``-merged across that mesh axis per
    bit — the paper's hierarchical reduction tree.
    """
    u = u.astype(jnp.uint32)
    if weights is None:
        w = jnp.ones(u.shape, jnp.float32)
    else:
        w = jnp.broadcast_to(weights.astype(jnp.float32), u.shape)

    total = w.sum(axis=0)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)

    # derive initial carries from the (possibly device-varying) data so the
    # fori_loop carry vma types are stable under shard_map
    active = u == u
    forced = (u & jnp.uint32(0)).astype(jnp.float32)
    # seed med from the (already psum-merged) totals so its replication type
    # matches the in-loop value under shard_map
    med = (total * 0.0).astype(jnp.uint32)

    def body(i, carry):
        active, forced, med = carry
        b = jnp.int32(bits - 1) - i
        bit = _bit_at(u, b)  # (N, ...)
        eff = jnp.where(active, bit, forced)
        cnt1 = (w * eff).sum(axis=0)
        if axis_name is not None:
            cnt1 = jax.lax.psum(cnt1, axis_name)
        mbit = cnt1 * 2.0 > total  # majority: 1 iff strictly more ones
        med = _set_bit(med, mbit, b)
        mbit_b = jnp.broadcast_to(mbit, u.shape)
        dev = active & (bit.astype(jnp.bool_) != mbit_b)
        forced = jnp.where(dev, bit, forced)
        active = active & ~dev
        return active, forced, med

    _, _, med = jax.lax.fori_loop(0, bits, body, (active, forced, med))
    return med


def grouped_median_bits(
    u,
    assign,
    k: int,
    *,
    weights=None,
    bits: int = 32,
    axis_name: Optional[str] = None,
):
    """Per-cluster bit-serial medians, all clusters in parallel.

    u: uint32 (N, D); assign: int32 (N,) in [0, k); weights: optional (N,).
    Returns (med (k, D) uint32, totals (k,) float32).  The per-bit vote count
    is a one-hot matmul (MXU-friendly); totals==0 marks empty clusters.
    """
    n, d = u.shape
    u = u.astype(jnp.uint32)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (N, K)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None]

    total = onehot.sum(axis=0)  # (K,)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)

    active = u == u
    forced = (u & jnp.uint32(0)).astype(jnp.float32)
    # seed med from the (already psum-merged) totals so its replication type
    # matches the in-loop value under shard_map
    med = jnp.zeros((k, d), jnp.uint32) | (total * 0.0).astype(jnp.uint32)[:, None]

    def body(i, carry):
        active, forced, med = carry
        b = jnp.int32(bits - 1) - i
        bit = _bit_at(u, b)  # (N, D)
        eff = jnp.where(active, bit, forced)
        # reduction "tree" level 1: within-shard one-hot matmul on the MXU
        cnt1 = jax.lax.dot_general(
            onehot, eff, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (K, D)
        # level 2: across shards
        if axis_name is not None:
            cnt1 = jax.lax.psum(cnt1, axis_name)
        mbit = cnt1 * 2.0 > total[:, None]  # (K, D) bool
        med = _set_bit(med, mbit, b)
        # broadcast each point's cluster-median bit back (gather)
        mper = jnp.take(mbit, assign, axis=0)  # (N, D)
        dev = active & (bit.astype(jnp.bool_) != mper)
        forced = jnp.where(dev, bit, forced)
        active = active & ~dev
        return active, forced, med

    _, _, med = jax.lax.fori_loop(0, bits, body, (active, forced, med))
    return med, total


def median_bits64(hi, lo, *, weights=None, axis_name: Optional[str] = None):
    """64-bit two-limb variant (paper's 64-bit fixed-point claim).

    hi, lo: uint32 (N, ...) limbs of an unsigned-ordered 64-bit value.
    Returns (med_hi, med_lo) uint32.
    """
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    if weights is None:
        w = jnp.ones(hi.shape, jnp.float32)
    else:
        w = jnp.broadcast_to(weights.astype(jnp.float32), hi.shape)
    total = w.sum(axis=0)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)

    active = hi == hi
    forced = (hi & jnp.uint32(0)).astype(jnp.float32)
    # seed medians from the (already psum-merged) totals so their replication
    # type matches the in-loop value under shard_map
    med_hi = (total * 0.0).astype(jnp.uint32)
    med_lo = (total * 0.0).astype(jnp.uint32)

    def body(i, carry):
        active, forced, med_hi, med_lo = carry
        b = jnp.int32(63) - i  # 63..0
        in_hi = b >= 32
        bshift = jnp.where(in_hi, b - 32, b)
        limb = jnp.where(in_hi, hi, lo)
        bit = _bit_at(limb, bshift)
        eff = jnp.where(active, bit, forced)
        cnt1 = (w * eff).sum(axis=0)
        if axis_name is not None:
            cnt1 = jax.lax.psum(cnt1, axis_name)
        mbit = cnt1 * 2.0 > total
        med_hi = jnp.where(in_hi, _set_bit(med_hi, mbit, bshift), med_hi)
        med_lo = jnp.where(in_hi, med_lo, _set_bit(med_lo, mbit, bshift))
        mbit_b = jnp.broadcast_to(mbit, hi.shape)
        dev = active & (bit.astype(jnp.bool_) != mbit_b)
        forced = jnp.where(dev, bit, forced)
        active = active & ~dev
        return active, forced, med_hi, med_lo

    _, _, med_hi, med_lo = jax.lax.fori_loop(
        0, 64, body, (active, forced, med_hi, med_lo)
    )
    return med_hi, med_lo


# ---------------------------------------------------------------------------
# Float front ends (quantize → bit-serial scan → dequantize)
# ---------------------------------------------------------------------------


def median(x, *, bits: int = 32, scale=None, weights=None,
           axis_name: Optional[str] = None):
    """Bit-serial median of float data along axis 0 (per remaining dims)."""
    if scale is None:
        scale = quantizer.auto_scale(
            x.reshape(x.shape[0], -1), bits
        ).reshape(x.shape[1:]) if x.ndim > 1 else quantizer.auto_scale(
            x[:, None], bits
        )[0]
    b = min(bits, 32)
    spec = quantizer.FixedPointSpec(bits=b, scale=scale)
    q = quantizer.quantize(x, spec)
    u = quantizer.to_unsigned_order(q, bits=b)
    med_u = median_bits(u, weights=weights, bits=b, axis_name=axis_name)
    return quantizer.dequantize(quantizer.from_unsigned_order(med_u, bits=b),
                                spec)


def grouped_median(x, assign, k: int, *, bits: int = 32, scale=None,
                   weights=None, axis_name: Optional[str] = None):
    """Per-cluster float medians: x (N, D), assign (N,) → ((k, D), totals)."""
    if scale is None:
        scale = quantizer.auto_scale(x, bits)
    b = min(bits, 32)
    spec = quantizer.FixedPointSpec(bits=b, scale=scale)
    q = quantizer.quantize(x, spec)
    u = quantizer.to_unsigned_order(q, bits=b)
    med_u, totals = grouped_median_bits(
        u, assign, k, weights=weights, bits=b, axis_name=axis_name
    )
    return (quantizer.dequantize(quantizer.from_unsigned_order(med_u, bits=b),
                                 spec), totals)


def sort_median_ref(x, axis=0):
    """Sort-based lower-median oracle (the semantics the majority tie-break
    yields): element at 1-based rank ceil(N/2)."""
    n = x.shape[axis]
    xs = jnp.sort(x, axis=axis)
    idx = (n + 1) // 2 - 1
    return jnp.take(xs, idx, axis=axis)
