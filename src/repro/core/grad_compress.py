"""Cross-pod gradient compression via k-means codebook quantization.

The multi-pod mesh's slowest wire is the pod-to-pod link.  This module
compresses each gradient tensor to a k-entry codebook (the paper's
clustering engine applied 1-D to gradient values) + 4-bit indices before
the cross-pod reduction, with error feedback so the quantization error is
carried to the next step instead of lost (standard EF-SGD argument).

Compression model (k=16): 4 bits/element + k floats ≈ 8× fewer bytes than
fp32 across the pod link.  The codebook fit is a tiny 1-D k-means run per
tensor per step (few Lloyd iterations over a subsample).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    k: int = 16              # codebook entries (4-bit indices)
    iters: int = 8           # Lloyd iterations for the 1-D codebook
    sample: int = 4096       # subsample size for the fit
    error_feedback: bool = True


class EFState(NamedTuple):
    residual: object  # pytree like grads


def _fit_codebook_1d(x_flat, k: int, iters: int, sample: int):
    """1-D k-means codebook over (a subsample of) the values."""
    n = x_flat.shape[0]
    idx = (jnp.arange(sample) * jnp.maximum(n // sample, 1)) % jnp.maximum(n, 1)
    xs = x_flat[idx]
    lo, hi = jnp.min(xs), jnp.max(xs)
    cents = lo + (hi - lo) * (jnp.arange(k, dtype=jnp.float32) + 0.5) / k

    def body(_, c):
        d = jnp.abs(xs[:, None] - c[None, :])
        a = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(a, k, dtype=jnp.float32)
        sums = onehot.T @ xs
        counts = onehot.sum(0)
        return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c)

    return jax.lax.fori_loop(0, iters, body, cents)


def quantize_tensor(g, cfg: CompressConfig):
    """Returns (indices uint8, codebook (k,)) for tensor g."""
    flat = g.reshape(-1).astype(jnp.float32)
    cents = _fit_codebook_1d(flat, cfg.k, cfg.iters,
                             min(cfg.sample, flat.shape[0]))
    d = jnp.abs(flat[:, None] - cents[None, :])
    idx = jnp.argmin(d, axis=1).astype(jnp.uint8)
    return idx.reshape(g.shape), cents


def dequantize_tensor(idx, cents):
    return jnp.take(cents, idx.astype(jnp.int32), axis=0)


def compress_decompress(g, cfg: CompressConfig):
    """Round-trip (what the wire sees): returns (g_hat, err)."""
    idx, cents = quantize_tensor(g, cfg)
    g_hat = dequantize_tensor(idx, cents)
    return g_hat, g - g_hat


def make_grad_transform(cfg: CompressConfig, axis_name: str = None):
    """Gradient transform for the optimizer hook.

    Without error feedback this is a pure transform; with it the caller
    threads EFState explicitly via ``apply_ef``.  Under pjit the cross-pod
    all-reduce happens on the *quantized* values; here we model the
    quantize→reduce→dequantize round trip (the compression error is what
    matters for convergence; wire-byte savings are reported analytically in
    the benchmarks).
    """
    def transform(grads):
        def one(g):
            if g.size < 1024:  # tiny tensors aren't worth compressing
                return g
            g_hat, _ = compress_decompress(g, cfg)
            return g_hat.astype(g.dtype)
        return jax.tree.map(one, grads)

    return transform


def apply_ef(grads, ef: EFState, cfg: CompressConfig):
    """Error-feedback round: compress (grads + residual), carry new residual."""
    def one(g, r):
        if g.size < 1024:
            return g, jnp.zeros_like(g)
        gc = g.astype(jnp.float32) + r
        g_hat, err = compress_decompress(gc, cfg)
        return g_hat.astype(g.dtype), err

    pairs = jax.tree.map(one, grads, ef.residual)
    g_hat = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, EFState(res)


def init_ef(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def wire_bytes(params_tree, cfg: CompressConfig) -> dict:
    """Analytic wire-byte comparison for one cross-pod all-reduce."""
    fp32 = sum(l.size * 4 for l in jax.tree.leaves(params_tree))
    comp = sum((l.size // 2 + cfg.k * 4) if l.size >= 1024 else l.size * 4
               for l in jax.tree.leaves(params_tree))
    return {"fp32_bytes": fp32, "compressed_bytes": comp,
            "ratio": fp32 / max(comp, 1)}
