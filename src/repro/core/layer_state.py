"""Layer-state families: *what* state a layer carries per slot, decoupled
from the ring-KV plumbing that stores it.

The serving tower grew up assuming every layer's per-slot state is a
position-indexed KV ring — dense, quantized, or clustered-with-paged-tails
— so admission, chunked absorb, compaction cadence, swap payloads, and the
end-of-serve invariants all reached straight into ring mechanics.  That
welded the engine to attention layers and rejected ``mamba2_2_7b`` /
``recurrentgemma_9b`` at the gate even though their model code exists.

This module names the distinction the same way :mod:`repro.core.retention`
named "what the cache retains":

* :class:`RingKVState` — position-indexed KV rings ('G' global attention,
  clustered/exact/quantized, optionally paged into pool blocks; 'L'
  sliding-window dense rings).  Grows with the stream; positions retire
  under a :class:`~repro.core.retention.RetentionPolicy`; tail bytes may
  live in shared pool blocks tracked by the block table.
* :class:`RecurrentState` — fixed-size running state per slot ('M' Mamba2
  SSD ``(conv, ssm)``; 'R' RG-LRU ``(conv, h)``).  Advanced inside the
  same mixed prefill+decode launch, one token at a time; nothing ever
  retires (see :class:`~repro.core.retention.RecurrentRetention`); never
  pool-backed, so block tables skip it entirely and its swap/prefix
  payload is the whole (small) state, checkpointed at chunk boundaries
  through the same opaque slot-snapshot format the clustered summaries
  use.

The engine asks families three questions: which kinds they cover
(:func:`family_of_kind`, :func:`families_for`), which cache leaves belong
to them (:func:`is_ring_leaf`, :func:`is_recurrent_leaf`), and how many
bytes a slot's state costs (:func:`recurrent_state_bytes`,
:func:`ring_tail_bytes_per_token`) — the Mettu–Plaxton cheapest-first
victim selection prices heterogeneous slots as
``mapped_blocks · block_bytes ⊕ recurrent_state_bytes``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

#: Per-leaf dict keys identifying a recurrent-state cache leaf.  Mamba2
#: carries {"conv", "ssm"}; RG-LRU carries {"conv", "h"}.  Snapshot /
#: restore / swap move *every* key of the leaf (the whole state is the
#: checkpoint — there is no tail to leave behind in pool blocks).
RECURRENT_LEAF_KEYS: Tuple[Tuple[str, ...], ...] = (("conv", "ssm"),
                                                    ("conv", "h"))

RING_KINDS = frozenset("GL")
RECURRENT_KINDS = frozenset("MR")


def family_of_kind(kind: str) -> str:
    """'ring' | 'recurrent' for a layer_pattern kind character."""
    if kind in RING_KINDS:
        return "ring"
    if kind in RECURRENT_KINDS:
        return "recurrent"
    raise ValueError(f"unknown layer kind {kind!r}")


@dataclass(frozen=True)
class RingKVState:
    """Ring-family descriptor: position-indexed KV, retention-governed."""

    kinds: frozenset
    family = "ring"
    pool_backed = True      # clustered tails / quota blocks live in the pool
    fixed_size = False      # state grows with the stream
    retirable = True        # positions retire behind a RetentionPolicy


@dataclass(frozen=True)
class RecurrentState:
    """Recurrent-family descriptor: fixed-size running state per slot."""

    kinds: frozenset
    family = "recurrent"
    pool_backed = False     # never in pool blocks; block tables skip it
    fixed_size = True       # (conv, ssm) / (conv, h) — constant per slot
    retirable = False       # nothing to retire; checkpoint, don't ring


@dataclass(frozen=True)
class LayerStateFamilies:
    """Which state families a config's layer pattern instantiates."""

    ring: RingKVState
    recurrent: RecurrentState

    @property
    def has_ring(self) -> bool:
        return bool(self.ring.kinds)

    @property
    def has_recurrent(self) -> bool:
        return bool(self.recurrent.kinds)

    @property
    def mixed(self) -> bool:
        return self.has_ring and self.has_recurrent


def families_for(cfg) -> LayerStateFamilies:
    """Classify a :class:`~repro.models.config.ModelConfig`'s layers.

    The unrolled MoE prefix layers (DeepSeek-style) are always global
    attention, so any ``moe.n_dense_layers > 0`` forces the ring family
    on even when the repeating pattern itself is attention-free.
    """
    kinds = set(cfg.layer_pattern)
    if cfg.moe is not None and cfg.moe.n_dense_layers > 0:
        kinds.add("G")
    unknown = kinds - RING_KINDS - RECURRENT_KINDS
    if unknown:
        raise ValueError(f"unknown layer kinds {sorted(unknown)!r} in "
                         f"pattern {cfg.layer_pattern!r}")
    return LayerStateFamilies(
        ring=RingKVState(kinds=frozenset(kinds & RING_KINDS)),
        recurrent=RecurrentState(kinds=frozenset(kinds & RECURRENT_KINDS)),
    )


# ---------------------------------------------------------------------------
# cache-leaf classification (shared by the engine's pytree walks)
# ---------------------------------------------------------------------------


def is_recurrent_leaf(node) -> bool:
    """A recurrent-state cache leaf: {"conv", "ssm"} or {"conv", "h"}."""
    return (isinstance(node, dict) and "conv" in node
            and ("ssm" in node or "h" in node))


def is_ring_leaf(node) -> bool:
    """A ring-family cache leaf: exact {"k","v"(,scales)}, clustered
    {"k_cents", ...}, or a window ring (same exact layout)."""
    return isinstance(node, dict) and ("k" in node or "k_cents" in node)


def recurrent_leaf_stacked(node) -> bool:
    """True when the leaf carries a leading ``lax.scan`` layer dim.

    Unstacked conv buffers are (B, k-1, C) / (B, 3, W) — 3 axes; the
    scan-stacked variant prepends the repeat dim.
    """
    return node["conv"].ndim == 4


# ---------------------------------------------------------------------------
# per-family byte accounting
# ---------------------------------------------------------------------------


def _walk_leaves(cache, pred):
    out = []

    def walk(node):
        if isinstance(node, dict):
            if pred(node):
                out.append(node)
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(cache)
    return out


def recurrent_state_bytes(cache, n_slots: int) -> int:
    """Total bytes of recurrent state one slot carries across all layers.

    Every recurrent leaf is slot-major (slot axis 0 unstacked, axis 1
    under a scan-stacked layer dim), so per-slot bytes are exactly
    ``total_bytes / n_slots``.  This is the swap/victim price of the
    recurrent family: the whole state moves, every time, and never
    shrinks.
    """
    total = 0
    for leaf in _walk_leaves(cache, is_recurrent_leaf):
        for k in leaf:
            a = leaf[k]
            total += int(a.size) * int(a.dtype.itemsize)
    return total // max(int(n_slots), 1)


def ring_state_bytes(cache, n_slots: int) -> int:
    """Bytes of dense ring-family state one slot carries (centroid
    summaries, dense/window rings, scales) — excludes pool-backed tail
    blocks, which are priced per mapped block by the engine."""
    total = 0
    for leaf in _walk_leaves(cache, is_ring_leaf):
        for k, a in leaf.items():
            if k in ("k_tail", "v_tail"):
                # tail payloads are priced separately: paged tails are
                # pool-global (no slot axis, priced per mapped block by
                # the engine); dense tails ride the ring ceiling
                continue
            total += int(a.size) * int(a.dtype.itemsize)
    return total // max(int(n_slots), 1)
