"""K-means / k-medians ("aggregations") clustering engine.

Implements the paper's Algorithm 1 loop (assign → recompute centroids until
convergence) with:

  * centroid = arithmetic mean (k-means) or bit-serial median (k-medians /
    the paper's "aggregations" variant, robust to outliers),
  * L2 or L1 assignment metric,
  * random or k-means++ initialization,
  * full-batch Lloyd, mini-batch, and a shard_map-distributed driver whose
    median update communicates only per-bit (K, D) vote counts — the paper's
    hierarchical reduction tree mapped onto the mesh data axis,
  * the paper's §4 optimal-k search (avgBMP loop) via simplified silhouette,
  * recognition-rate evaluation (paper Table 3 protocol: clusters take their
    majority label; accuracy of that labeling).

Everything is jit-compatible; the Pallas assignment kernel is wired in via
``repro.kernels.ops`` (pure-jnp fallback used automatically on CPU).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bitserial, quantizer


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    k: int
    metric: str = "l1"            # "l1" | "l2"
    centroid: str = "median"      # "median" (paper) | "mean" (k-means)
    max_iters: int = 50
    tol: float = 1e-4
    init: str = "kmeanspp"        # "kmeanspp" | "random"
    bits: int = 32                # fixed-point width for the bit-serial scan
    seed: int = 0
    assign_chunk: int = 4096      # N-chunking for the assignment step


# ---------------------------------------------------------------------------
# Distances / assignment
# ---------------------------------------------------------------------------


def pairwise_dist(x, cents, metric: str):
    """x (n, D), cents (K, D) → (n, K) distances (L2 is squared L2)."""
    if metric == "l2":
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (n, 1)
        c2 = jnp.sum(cents * cents, axis=-1)[None, :]         # (1, K)
        xc = x @ cents.T                                      # MXU
        return jnp.maximum(x2 - 2.0 * xc + c2, 0.0)
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - cents[None, :, :]), axis=-1)
    raise ValueError(f"unknown metric {metric}")


def assign_points(x, cents, metric: str, chunk: int = 4096, use_kernel: bool = True):
    """Chunked assignment: returns (assign (N,), mindist (N,))."""
    if use_kernel:
        # late import to avoid a hard dependency cycle
        from repro.kernels import ops as kops

        return kops.distance_argmin(x, cents, metric=metric)
    return _assign_points_jnp(x, cents, metric, chunk)


def _assign_points_jnp(x, cents, metric: str, chunk: int = 4096):
    n, d = x.shape
    if n <= chunk:
        dist = pairwise_dist(x, cents, metric)
        return jnp.argmin(dist, axis=-1).astype(jnp.int32), jnp.min(dist, axis=-1)

    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xc = xp.reshape(-1, chunk, d)

    def one(xb):
        dist = pairwise_dist(xb, cents, metric)
        return jnp.argmin(dist, axis=-1).astype(jnp.int32), jnp.min(dist, axis=-1)

    a, m = jax.lax.map(one, xc)
    return a.reshape(-1)[:n], m.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_random(rng, x, k: int):
    idx = jax.random.choice(rng, x.shape[0], (k,), replace=False)
    return x[idx]


def init_kmeanspp(rng, x, k: int, metric: str = "l2", weights=None):
    """k-means++ (D^2 sampling; D^1 for L1/k-medians).  Optional point
    ``weights`` (N,) scale the sampling probabilities — zero-weight points
    (padding / masked slots) are never chosen as seeds."""
    n, d = x.shape
    r0, rloop = jax.random.split(rng)
    if weights is None:
        first = x[jax.random.randint(r0, (), 0, n)]
    else:
        wsum = weights.sum()
        probs0 = jnp.where(wsum > 0, weights / jnp.maximum(wsum, 1e-30),
                           jnp.full((n,), 1.0 / n))
        first = x[jax.random.choice(r0, n, p=probs0)]
    cents = jnp.zeros((k, d), x.dtype).at[0].set(first)
    mind = pairwise_dist(x, first[None, :], metric)[:, 0]

    def body(i, carry):
        cents, mind, key = carry
        key, sub = jax.random.split(key)
        w = mind if metric == "l2" else jnp.maximum(mind, 0.0)
        if weights is not None:
            w = w * weights
        wsum = w.sum()
        probs = jnp.where(wsum > 0, w / jnp.maximum(wsum, 1e-30),
                          jnp.full((n,), 1.0 / n))
        idx = jax.random.choice(sub, n, p=probs)
        c = x[idx]
        cents = cents.at[i].set(c)
        dnew = pairwise_dist(x, c[None, :], metric)[:, 0]
        return cents, jnp.minimum(mind, dnew), key

    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, mind, rloop))
    return cents


# ---------------------------------------------------------------------------
# Centroid updates
# ---------------------------------------------------------------------------


def seed_empty_centroids(x, cents, live, metric: str, weights=None):
    """Deterministically re-seed dead centroid rows by greedy farthest-point
    (maximin) selection over the weighted point set.

    ``cents`` (K, D) is a warm-start bank; rows with ``live`` False (e.g.
    count == 0) are replaced one at a time by the point farthest from every
    centroid placed so far (k-means++ with argmax instead of sampling, so
    the result is reproducible without threading RNG through the serving
    engine).  Live rows keep their values and shape the distance field.
    Zero-weight points (padding / masked ring slots) are never chosen.

    Needed by streaming admission (kv_compress.absorb_chunk): the first
    chunk of a request arrives with an all-zero centroid bank, and warm-
    starting Lloyd from K identical zero rows collapses every point into
    one cluster.  jit-compatible (fori_loop over K rows).
    """
    n, _ = x.shape
    k = cents.shape[0]
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    dist0 = pairwise_dist(x, cents, metric)               # (n, K)
    mind = jnp.min(jnp.where(live[None, :], dist0, jnp.inf), axis=1)
    # no live row yet → flat field: the first dead row takes the first
    # positively-weighted point, the rest spread by maximin from there
    mind = jnp.where(jnp.isfinite(mind), mind, 1.0)

    def body(i, carry):
        cents, mind = carry
        score = jnp.where(w > 0, mind, -1.0)
        c_new = x[jnp.argmax(score)]
        c_i = jnp.where(live[i], cents[i], c_new)
        cents = cents.at[i].set(c_i)
        d_new = pairwise_dist(x, c_i[None, :], metric)[:, 0]
        return cents, jnp.minimum(mind, d_new)

    cents, _ = jax.lax.fori_loop(0, k, body, (cents, mind))
    return cents


def update_mean(x, assign, k: int, prev, *, weights=None,
                axis_name: Optional[str] = None):
    """Weighted mean centroids; mirrors ``update_median``'s signature so the
    Lloyd driver treats both centroid kinds uniformly.  Under shard_map the
    per-cluster sums/counts psum over ``axis_name`` — the same reduction
    tree the bit-serial median votes use, so mean and median fits are
    psum-consistent with each other."""
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None]
    sums = onehot.T @ x
    counts = onehot.sum(axis=0)
    if axis_name is not None:
        sums = jax.lax.psum(sums, axis_name)
        counts = jax.lax.psum(counts, axis_name)
    mean = sums / jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(counts[:, None] > 0, mean, prev), counts


def update_median(x, assign, k: int, prev, *, bits: int = 32, scale=None,
                  weights=None, axis_name: Optional[str] = None):
    med, counts = bitserial.grouped_median(
        x, assign, k, bits=bits, scale=scale, weights=weights,
        axis_name=axis_name
    )
    return jnp.where(counts[:, None] > 0, med, prev), counts


# ---------------------------------------------------------------------------
# Lloyd driver
# ---------------------------------------------------------------------------


class ClusterResult(NamedTuple):
    centroids: jnp.ndarray
    assign: jnp.ndarray
    inertia: jnp.ndarray
    n_iters: jnp.ndarray
    counts: jnp.ndarray


def _one_iter(cfg: ClusterConfig, x, cents, scale, axis_name=None,
              use_kernel=True, weights=None):
    assign, mind = assign_points(x, cents, cfg.metric, cfg.assign_chunk,
                                 use_kernel=use_kernel)
    if cfg.centroid == "mean":
        new, counts = update_mean(x, assign, cfg.k, cents, weights=weights,
                                  axis_name=axis_name)
    else:
        new, counts = update_median(x, assign, cfg.k, cents, bits=cfg.bits,
                                    scale=scale, weights=weights,
                                    axis_name=axis_name)
    inertia = mind.sum() if weights is None else (mind * weights).sum()
    if axis_name is not None:
        inertia = jax.lax.psum(inertia, axis_name)
    return new, assign, counts, inertia


def fit(x, cfg: ClusterConfig, init_centroids=None, *, use_kernel: bool = True,
        weights=None, axis_name: Optional[str] = None) -> ClusterResult:
    """Full-batch Lloyd iterations until convergence (jit-compatible).

    Optional ``weights`` (N,) ≥ 0 make this a weighted clustering: padded /
    masked points get weight 0 and never influence centroids, counts, or
    inertia; integer weights > 1 treat a point as a pre-aggregated summary
    of that many originals (streaming re-clustering of cluster summaries).

    Under shard_map, pass ``axis_name`` and per-device shards of x; init
    centroids must then be provided (replicated) by the caller.
    """
    rng = jax.random.PRNGKey(cfg.seed)
    if init_centroids is None:
        if axis_name is not None:
            raise ValueError("distributed fit requires init_centroids")
        init_centroids = (
            init_kmeanspp(rng, x, cfg.k, cfg.metric, weights=weights)
            if cfg.init == "kmeanspp"
            else init_random(rng, x, cfg.k)
        )
    # one shared fixed-point scale for the whole run (paper: single 2^f);
    # zero-weight (masked) points must not widen the scale
    x_scale = x if weights is None else x * (weights > 0)[:, None].astype(x.dtype)
    scale = quantizer.auto_scale(x_scale, cfg.bits)
    if axis_name is not None:
        # global per-feature scale: max over shards
        scale = jax.lax.pmin(scale, axis_name)  # min scale = max |x| wins

    def cond(state):
        cents, _, it, moved, _, _ = state
        return jnp.logical_and(it < cfg.max_iters, moved > cfg.tol)

    def body(state):
        cents, _, it, _, _, _ = state
        new, assign, counts, inertia = _one_iter(
            cfg, x, cents, scale, axis_name=axis_name, use_kernel=use_kernel,
            weights=weights
        )
        moved = jnp.max(jnp.abs(new - cents))
        return new, assign, it + 1, moved, counts, inertia

    # assign is per-shard (device-varying under shard_map): derive the
    # initial value from x so the loop carry types are stable
    assign0 = (x[:, 0] * 0).astype(jnp.int32)
    if axis_name is None:
        state0 = (
            init_centroids,
            assign0,
            jnp.int32(0),
            jnp.float32(jnp.inf),
            jnp.zeros((cfg.k,), jnp.float32),
            jnp.float32(0.0),
        )
        cents, assign, it, _, counts, inertia = jax.lax.while_loop(
            cond, body, state0)
    else:
        # while_loop has no shard_map replication rule: run a fixed-trip
        # fori_loop and freeze the state once converged — same fixpoint as
        # the early-exit loop, and scan-lowered so the per-bit psum carries
        # keep consistent replication types.
        rzero = jax.lax.psum(jnp.zeros((), jnp.float32), axis_name)

        def fori_body(_, state):
            converged = ~cond(state)
            new_state = body(state)
            return jax.tree_util.tree_map(
                lambda old, new: jnp.where(converged, old, new),
                state, new_state)

        state0 = (
            init_centroids,
            assign0,
            rzero.astype(jnp.int32),
            jnp.float32(jnp.inf) + rzero,
            jnp.zeros((cfg.k,), jnp.float32) + rzero,
            rzero,
        )
        cents, assign, it, _, counts, inertia = jax.lax.fori_loop(
            0, cfg.max_iters, fori_body, state0)
    return ClusterResult(cents, assign, inertia, it, counts)


def fit_minibatch(rng, x, cfg: ClusterConfig, batch_size: int, n_steps: int,
                  init_centroids=None) -> ClusterResult:
    """Mini-batch variant: per step sample a batch, assign, and blend the
    batch centroid (mean or bit-serial median) into the running centroid with
    a per-cluster learning rate 1/visit-count (Sculley-style)."""
    if init_centroids is None:
        r0, rng = jax.random.split(rng)
        init_centroids = init_kmeanspp(r0, x, cfg.k, cfg.metric)
    scale = quantizer.auto_scale(x, cfg.bits)

    def step(carry, key):
        cents, visits = carry
        idx = jax.random.randint(key, (batch_size,), 0, x.shape[0])
        xb = x[idx]
        assign, _ = _assign_points_jnp(xb, cents, cfg.metric)
        if cfg.centroid == "mean":
            batch_c, counts = update_mean(xb, assign, cfg.k, cents)
        else:
            batch_c, counts = update_median(xb, assign, cfg.k, cents,
                                            bits=cfg.bits, scale=scale)
        visits = visits + counts
        lr = jnp.where(counts > 0, counts / jnp.maximum(visits, 1.0), 0.0)
        cents = cents + lr[:, None] * (batch_c - cents)
        return (cents, visits), None

    keys = jax.random.split(rng, n_steps)
    (cents, visits), _ = jax.lax.scan(step, (init_centroids,
                                             jnp.zeros((cfg.k,), jnp.float32)),
                                      keys)
    assign, mind = _assign_points_jnp(x, cents, cfg.metric)
    return ClusterResult(cents, assign, mind.sum(), jnp.int32(n_steps), visits)


# ---------------------------------------------------------------------------
# Quality metrics / model selection (paper §4, Table 3)
# ---------------------------------------------------------------------------


def simplified_silhouette(x, cents, assign):
    """Simplified silhouette (centroid-based): (b - a) / max(a, b).  This is
    the 'avgBMP' style per-sample quality score the paper's optimal-k loop
    averages."""
    dist = pairwise_dist(x, cents, "l2")
    k = cents.shape[0]
    a = jnp.take_along_axis(dist, assign[:, None], axis=1)[:, 0]
    masked = dist.at[jnp.arange(x.shape[0]), assign].set(jnp.inf)
    b = jnp.min(masked, axis=1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-30)
    return s.mean()


def select_k(x, kmin: int, kmax: int, cfg: ClusterConfig):
    """Paper §4: sweep k in [kmin, kmax], call k-means, compute avgBMP(k),
    return (k_opt, scores).  Python loop — k changes shapes."""
    scores = []
    for k in range(kmin, kmax + 1):
        c = dataclasses.replace(cfg, k=k)
        res = jax.jit(partial(fit, cfg=c, use_kernel=False))(x)
        scores.append(float(simplified_silhouette(x, res.centroids, res.assign)))
    k_opt = kmin + int(jnp.argmax(jnp.asarray(scores)))
    return k_opt, scores


def recognition_rate(assign, labels, k: int, n_classes: int):
    """Paper Table 3 protocol: each cluster adopts its majority true label;
    report the fraction of points whose cluster-label matches their own."""
    conf = jnp.zeros((k, n_classes), jnp.float32)
    conf = conf.at[assign, labels].add(1.0)
    cluster_label = jnp.argmax(conf, axis=1)
    pred = cluster_label[assign]
    return (pred == labels).mean()
