"""Fixed-point conversion front end (paper §4).

The paper scales floating-point inputs by a power of two and truncates to a
fixed-point representation before the bit-serial pipeline ("The input floating
point data are scaled by a factor of 2^f and then are converted to fixed-point
data"), observing that 64-bit fixed point matches IEEE double for its
clustering workloads.  We implement:

  * int32 fixed point (default, validated to match float medians to 1 ulp of
    the chosen scale),
  * an int64-equivalent two-limb (hi, lo) uint32 path for the paper's 64-bit
    claim (JAX x64 stays disabled),
  * per-feature power-of-two auto-scaling.

Sign handling: two's-complement values are mapped to an unsigned-comparable
ordering by flipping the sign bit (u = x XOR 0x8000_0000), so lexicographic
bit order == numeric order, which the bit-serial scan requires.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SIGN32 = np.uint32(0x80000000)


@dataclasses.dataclass(frozen=True)
class FixedPointSpec:
    """Quantization spec. ``scale`` maps float -> fixed: q = round(x * scale).

    ``scale`` may be a scalar or a per-feature (broadcastable) array of
    powers of two, mirroring the paper's 2^f scaling.
    """

    bits: int = 32
    scale: object = 1.0  # float scalar or array

    def __post_init__(self):
        if self.bits not in (8, 16, 32, 64):
            raise ValueError(f"unsupported fixed-point width {self.bits}")


def auto_scale(x, bits: int = 32, margin_bits: int = 2):
    """Per-feature power-of-two scale so data spans the fixed-point range.

    Leaves ``margin_bits`` of headroom (sums/medians never overflow the
    representation).  Accepts (N, D) and returns (D,) scales.
    """
    absmax = jnp.max(jnp.abs(x), axis=0)
    absmax = jnp.maximum(absmax, 1e-30)
    # largest f with absmax * 2^f <= 2^(bits-1-margin); cap so the scale
    # stays finite in float32 even for all-zero (fully masked) features
    f = jnp.floor((bits - 1 - margin_bits) - jnp.log2(absmax))
    return jnp.exp2(jnp.minimum(f, 126.0))


def quantize(x, spec: FixedPointSpec):
    """float -> signed fixed point.  Returns int32 for bits<=32, (hi, lo)
    uint32 limbs for bits=64."""
    scaled = x * spec.scale
    if spec.bits <= 32:
        lim = float(2 ** (spec.bits - 1) - 1)
        q = jnp.clip(jnp.round(scaled), -lim - 1, lim)
        return q.astype(jnp.int32)
    # 64-bit: host-grade encode done in float64 is unavailable in-graph
    # (x64 disabled); split into hi/lo limbs from a float32 value.  The extra
    # 32 fractional bits only matter when encoding float64 host data — see
    # ``quantize64_host`` below, used by tests/benchmarks.
    lim = float(2**31 - 1)
    hi = jnp.clip(jnp.floor(scaled / (2.0**32)), -lim - 1, lim).astype(jnp.int32)
    lo = (scaled - hi.astype(jnp.float32) * (2.0**32)).astype(jnp.uint32)
    return hi, lo


def dequantize(q, spec: FixedPointSpec):
    if spec.bits <= 32:
        return q.astype(jnp.float32) / spec.scale
    hi, lo = q
    val = hi.astype(jnp.float32) * (2.0**32) + lo.astype(jnp.float32)
    return val / spec.scale


def quantize64_host(x: np.ndarray, scale) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (numpy float64) 64-bit fixed-point encode: returns
    unsigned-comparable (hi, lo) uint32 limbs (sign bit already flipped)."""
    q = np.clip(np.round(np.asarray(x, np.float64) * scale), -(2.0**63), 2.0**63 - 1)
    qi = q.astype(np.int64)
    u = qi.astype(np.uint64) ^ np.uint64(0x8000000000000000)
    hi = (u >> np.uint64(32)).astype(np.uint32)
    lo = (u & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return hi, lo


def dequantize64_host(hi: np.ndarray, lo: np.ndarray, scale) -> np.ndarray:
    u = (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)
    qi = (u ^ np.uint64(0x8000000000000000)).astype(np.int64)
    return qi.astype(np.float64) / scale


def to_unsigned_order(q_int32, bits: int = 32):
    """Signed fixed point (stored in int32) -> unsigned-comparable uint32:
    flip the sign bit *of the fixed-point width* and mask to that width, so a
    ``bits``-bit MSB→LSB scan sees numeric order."""
    sign = jnp.uint32(1 << (bits - 1))
    u = q_int32.astype(jnp.uint32) ^ sign
    if bits < 32:
        u = u & jnp.uint32((1 << bits) - 1)
    return u


def from_unsigned_order(u_uint32, bits: int = 32):
    if bits == 32:
        return (u_uint32 ^ jnp.uint32(SIGN32)).astype(jnp.int32)
    sign = jnp.uint32(1 << (bits - 1))
    v = ((u_uint32 ^ sign) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)
    return jnp.where(v >= (1 << (bits - 1)), v - (1 << bits), v)
