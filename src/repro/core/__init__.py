# The paper's primary contribution: bit-serial majority-vote medians and the
# clustering engine built on them, plus the framework features they power
# (KV-cache compression, request batching, gradient compression).
from repro.core import bitserial, clustering, quantizer  # noqa: F401
