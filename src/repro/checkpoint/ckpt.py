"""Checkpointing: pytree save/restore with step resume and elastic re-shard.

Format: one directory per step —
    ckpt_dir/step_000123/
        meta.json              (step, tree structure, leaf dtypes/shapes)
        arrays.npz             (flat leaves, key = leaf path)
        DONE                   (commit marker — atomic rename protocol)

Fault-tolerance properties:
  * atomic commit: writers write to ``.tmp`` then rename; a crash mid-save
    leaves no DONE marker and the restore picks the previous step,
  * elastic restore: arrays are saved unsharded (host-gathered); on restore
    they are placed against the *current* mesh's shardings, so a job may
    restart on a different topology,
  * async save: a background thread serializes a host snapshot taken at
    call time (jax.device_get), so the train loop is blocked only for the
    device→host copy, not the disk write.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

import ml_dtypes

# dtypes numpy's npz cannot round-trip: store a bit-identical uint view and
# record the logical dtype in meta.json
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}
_UNVIEW = {"bfloat16": ml_dtypes.bfloat16,
           "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
           "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(_k(k) for k in kp)
        arr = np.asarray(leaf)
        if str(arr.dtype) in _VIEW:
            arr = arr.view(_VIEW[str(arr.dtype)])
        out[key] = arr
    return out


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(ckpt_dir: str, step: int, tree: Any, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Save ``tree`` at ``step``.  Non-blocking mode snapshots to host then
    writes in a daemon thread; returns the thread."""
    host_tree = jax.device_get(tree)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        flat0, _ = jax.tree_util.tree_flatten_with_path(host_tree)
        logical = {"/".join(_k(k) for k in kp): str(np.asarray(l).dtype)
                   for kp, l in flat0}
        arrays = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        meta = {"step": step,
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": logical[k]}
                           for k, v in arrays.items()}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    th = threading.Thread(target=_write, daemon=True)
    th.start()
    return th


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "DONE")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like_tree: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like_tree``.  With ``shardings``
    (a matching pytree of jax.sharding.Sharding) leaves are placed sharded
    against the *current* mesh — elastic restart."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    svals = None
    if shardings is not None:
        svals = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )[0]
    leaves = []
    for i, (kp, like) in enumerate(flat):
        key = "/".join(_k(k) for k in kp)
        arr = data[key]
        dt = meta["leaves"][key]["dtype"]
        if dt in _UNVIEW:
            arr = arr.view(_UNVIEW[dt])
        assert tuple(arr.shape) == tuple(like.shape), (key, arr.shape,
                                                       like.shape)
        if svals is not None:
            leaves.append(jax.device_put(arr, svals[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def prune(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "DONE")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
