from repro.sharding.rules import (Rules, annotate, annotate_prio,
                                  current_rules, default_table, param_spec,
                                  shardings_from_specs, tree_param_specs,
                                  use_rules)  # noqa: F401
