from repro.sharding.rules import (Rules, admission_spec, annotate,
                                  annotate_prio, block_table_spec,
                                  cache_spec, constrain_cache,
                                  current_rules, default_table, param_spec,
                                  place_admission, place_block_tables,
                                  place_prefix_snapshot,
                                  place_swap_payload,
                                  shard_cache, shardings_from_specs,
                                  tree_param_specs, use_rules)  # noqa: F401
