from repro.sharding.rules import (Rules, annotate, annotate_prio, cache_spec,
                                  constrain_cache, current_rules,
                                  default_table, param_spec, shard_cache,
                                  shardings_from_specs, tree_param_specs,
                                  use_rules)  # noqa: F401
