"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Models annotate intermediates with *logical* axis names via ``annotate``;
a rules context (installed by the launcher around tracing) maps logical
names to mesh axes and applies ``with_sharding_constraint``.  Outside a
context ``annotate`` is a no-op, so model code never depends on a mesh.

Parameter partition specs are derived from leaf *names* + shapes
(``param_spec``) with the same divisibility rule: a dimension is sharded
only when its size divides evenly; otherwise it is replicated (never
crash — small models on big meshes degrade gracefully to partial TP).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    table: dict                      # logical axis -> mesh axis tuple | None
    fsdp: bool = False               # shard params/opt-state over data axis

    def axes_for(self, logical: Optional[str], dim: int):
        if logical is None:
            return None
        axes = self.table.get(logical)
        if not axes:
            return None
        total = math.prod(self.mesh.shape[a] for a in axes)
        if dim % total != 0:
            # try a prefix of the axes (e.g. batch over ("pod","data") but
            # dim only divisible by pod count)
            for cut in range(len(axes) - 1, 0, -1):
                sub = axes[:cut]
                t = math.prod(self.mesh.shape[a] for a in sub)
                if dim % t == 0:
                    return tuple(sub)
            return None
        return tuple(axes)


_ACTIVE: list = []


@contextlib.contextmanager
def use_rules(rules: Rules):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> Optional[Rules]:
    return _ACTIVE[-1] if _ACTIVE else None


def annotate(x, *logical_axes):
    """Constrain intermediate ``x`` (ndim == len(logical_axes)) if a rules
    context is active; otherwise identity.  A mesh axis may appear at most
    once — the first (leftmost) logical axis that claims it wins (e.g. the
    MoE expert dim takes ``model`` and the expert-FFN dim then replicates)."""
    r = current_rules()
    if r is None:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    used = set()
    dims = []
    for ax, d in zip(logical_axes, x.shape):
        res = r.axes_for(ax, d)
        tup = (res,) if isinstance(res, str) else tuple(res or ())
        if not tup or any(a in used for a in tup):
            dims.append(None)
        else:
            used.update(tup)
            dims.append(res)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*dims)))


def annotate_prio(x, logical_axes, priority):
    """Like ``annotate`` but resolves logical axes in ``priority`` order
    (indices into logical_axes), so e.g. the MoE expert dim claims the
    (model, data) axes before the dispatch-shard dim claims data."""
    r = current_rules()
    if r is None:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    used = set()
    dims = [None] * x.ndim
    order = list(priority) + [i for i in range(x.ndim) if i not in priority]
    for i in order:
        ax = logical_axes[i]
        if ax is None:
            continue
        res = r.axes_for(ax, x.shape[i])
        tup = (res,) if isinstance(res, str) else tuple(res or ())
        if not tup or any(a in used for a in tup):
            continue
        used.update(tup)
        dims[i] = res
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*dims)))


# ---------------------------------------------------------------------------
# Mesh-axis tables
# ---------------------------------------------------------------------------


def default_table(multi_pod: bool, *, seq_shard: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    model = ("model",)
    t = {
        "batch": batch,
        "seq": None,
        "kvseq": batch if seq_shard else None,  # sequence-parallel KV (SP)
        "d_model": None,
        "heads": model,
        "kv_heads": model,
        "ff": model,
        "vocab": model,
        # full expert parallelism: spread experts over model×data when the
        # count divides (DeepSeek 256 → 1 expert/chip; axes_for falls back
        # to ("model",) then replication for awkward counts like Qwen2's 60)
        "experts": ("model", "data"),
        "expert_ff": model,
        "expert_cap": batch,
        "lru": model,
        "ssm_heads": model,
        "state": None,
        "head_dim": None,
    }
    return t


# ---------------------------------------------------------------------------
# Parameter partition specs (name-based)
# ---------------------------------------------------------------------------

# rule: regex on the leaf path -> logical axes for the TRAILING dims
_PARAM_RULES = [
    # MoE expert banks: (E, d, f) / (E, f, d)
    (re.compile(r"moe/(w_gate|w_up)$"), ("experts", "fsdp", "expert_ff")),
    (re.compile(r"moe/w_down$"), ("experts", "expert_ff", "fsdp")),
    (re.compile(r"moe/router$"), (None, None)),
    (re.compile(r"moe/bias$"), (None,)),
    # embeddings / heads
    (re.compile(r"embed/table$"), ("vocab", "fsdp")),
    (re.compile(r"embed/head$"), ("fsdp", "vocab")),
    # attention projections
    (re.compile(r"(wq|wk|wv|wuq|wukv)$"), ("fsdp", "model_out")),
    (re.compile(r"(wdq|wdkv|wkr)$"), ("fsdp", None)),
    (re.compile(r"wo$"), ("model_out", "fsdp")),
    # mlp
    (re.compile(r"(w_gate|w_up)$"), ("fsdp", "ff")),
    (re.compile(r"w_down$"), ("ff", "fsdp")),
    # recurrent / ssm
    (re.compile(r"(wx|wg|wa_gate|wi_gate)$"), ("fsdp", "lru")),
    (re.compile(r"rg_out$"), ("lru", "fsdp")),
    (re.compile(r"in_proj$"), ("fsdp", "ssm_ch")),
    (re.compile(r"out_proj$"), ("ssm_ch", "fsdp")),
    (re.compile(r"frontend/proj$"), (None, "fsdp")),
]


def param_spec(path: str, shape: Sequence[int], rules: Rules) -> P:
    """Partition spec for parameter leaf ``path`` with ``shape``.

    Trailing dims follow the matched rule; extra leading dims (layer-stacking
    from scan) are unsharded.  ``fsdp`` resolves to the data axis when the
    rules enable it (ZeRO-style), else replicated.  ``model_out``/``ff`` etc.
    resolve to the model axis when divisible.
    """
    logical = None
    for rx, ax in _PARAM_RULES:
        if rx.search(path):
            logical = ax
            break
    if logical is None:
        return P()  # norms, biases, conv kernels, A_log… replicated

    def resolve(name, dim):
        if name is None:
            return None
        if name == "fsdp":
            if not rules.fsdp:
                return None
            axes = rules.table.get("batch") or ()
            # fsdp uses the data axis only (not pod — pods replicate params
            # unless fsdp spans pods; keep intra-pod to bound cross-pod
            # traffic, cross-pod handled by gradient compression)
            axes = tuple(a for a in axes if a == "data")
            total = math.prod(rules.mesh.shape[a] for a in axes) if axes else 0
            return axes if axes and dim % total == 0 else None
        if name == "experts":
            return rules.axes_for("experts", dim)
        if name in ("model_out", "ff", "expert_ff", "vocab", "lru",
                    "ssm_ch", "heads"):
            axes = ("model",)
            total = rules.mesh.shape["model"]
            return axes if dim % total == 0 else None
        axes = rules.table.get(name)
        if not axes:
            return None
        total = math.prod(rules.mesh.shape[a] for a in axes)
        return tuple(axes) if dim % total == 0 else None

    trailing = [resolve(n, d) for n, d in zip(logical, shape[-len(logical):])]
    lead = [None] * (len(shape) - len(logical))
    used = set()
    final = list(lead)
    # a mesh axis may appear at most once in a spec; drop duplicates (e.g.
    # fsdp=data colliding with expert_cap) keeping the first occurrence
    for ax in trailing:
        if ax is None:
            final.append(None)
            continue
        tup = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a in used for a in tup):
            final.append(None)
        else:
            used.update(tup)
            final.append(ax)
    return P(*final)


# ---------------------------------------------------------------------------
# Serving-cache partition specs (name-based, like params)
# ---------------------------------------------------------------------------

# KV-cache leaf name -> head-axis position counted from the END of the shape
_CACHE_HEAD_AXIS = {
    "k": 2, "v": 2,                                   # (…, S, H, Dh)
    "k_cents": 2, "v_cents": 2,                       # (…, C, H, Dh)
    "k_tail": 2, "v_tail": 2,                         # (…, R, H, Dh)
    "counts": 1,                                      # (…, C, H)
}


def cache_spec(path: str, shape: Sequence[int], rules: Rules) -> P:
    """Partition spec for one serving-cache leaf.

    Decode slots (the engine batch axis — axis 0, or axis 1 under the
    scan-stacked leading layer dim) partition over the rules' ``batch``
    mesh axes; KV head dims partition over the model axis.  Divisibility-
    aware like ``param_spec``: a dim that doesn't divide is replicated, so
    small models on big meshes degrade to partial parallelism instead of
    crashing.  Non-KV state (MLA latents, SSM/RG-LRU state, int8 scales)
    gets slot sharding only.

    Paged tail pools (runtime/kv_pool.py) flow through the same rule: a
    pool leaf ``k_tail (n_blocks, block_size, H, Dh)`` shards its leading
    block axis over the ``batch`` mesh axes — the pool is sized
    ``shards × pool_blocks``, NamedSharding partitions the axis
    contiguously, and the allocator hands each shard's slots only that
    shard's block-id range, so the pool shards over ``data`` exactly like
    the slots it backs (same for the scan-stacked ``(L, n_blocks, …)``
    form via the layer-dim shift).

    Retention-policy state (core/retention.py) needs no rules of its
    own: the device ``cov`` leaf FrontierRetention mirrors is batch-only
    (slot per data shard, like every per-slot scalar here), sliding-
    window 'L' rings are ordinary dense ``k``/``v`` ring leaves (window-
    sized, never pool-backed) that shard via ``_CACHE_HEAD_AXIS``, the
    per-row ``wlo`` window floors ship with the launch over ``data``
    like ``cov`` (kernels' shard_map specs), and WindowRetention /
    QuotaRetention bookkeeping is host-side numpy that never touches the
    mesh.
    """
    parts = path.split("/")
    name = parts[-1]
    stacked = parts[0] == "scan"
    dims: list = [None] * len(shape)
    used: set = set()

    def put(axis_pos: int, logical: str):
        if not 0 <= axis_pos < len(shape):
            return
        res = rules.axes_for(logical, shape[axis_pos])
        tup = (res,) if isinstance(res, str) else tuple(res or ())
        if tup and not any(a in used for a in tup):
            used.update(tup)
            dims[axis_pos] = res

    if name in ("k_scale", "v_scale"):                # (…, H) — no slot dim
        put(len(shape) - 1, "kv_heads")
        return P(*dims)
    put(1 if stacked else 0, "batch")
    head_off = _CACHE_HEAD_AXIS.get(name)
    if head_off is not None and len(shape) - head_off > (1 if stacked else 0):
        put(len(shape) - head_off, "kv_heads")
    return P(*dims)


def block_table_spec(shape: Sequence[int], rules: Rules) -> P:
    """Partition spec for the paged engine's block table ``(slots, T)``:
    rows follow the slots over the ``batch`` mesh axes (each shard sees
    only its own slots' rows — entries hold global block ids that the
    shard_map island rebases locally), ring-block columns replicated."""
    dims: list = [None] * len(shape)
    res = rules.axes_for("batch", shape[0])
    if res:
        dims[0] = res
    return P(*dims)


def place_block_tables(bt, rules: Rules):
    """Host-side mesh placement for the block table pushed each launch."""
    return jax.device_put(
        bt, NamedSharding(rules.mesh, block_table_spec(bt.shape, rules)))


def admission_spec(path: str, shape: Sequence[int], rules: Rules) -> P:
    """Partition spec for a B=1 admission-prefill cache leaf.

    A single request's cache can't shard its slot dim (size 1) and an
    array can't live on a strict subset of the jit's device set (jax
    requires one device assignment per computation), so the data-axis
    copy is unavoidable for the *blocking* admission path — but the
    kv-head dims CAN shard over the model axis, cutting the admission
    transfer volume by the model-parallel factor versus the old
    replicate-everything ``P()`` placement.  The chunked admission path
    removes the B=1 cache entirely (prompt KV streams into the already-
    sharded engine slots), which is the complete fix.
    """
    name = path.split("/")[-1]
    dims: list = [None] * len(shape)
    head_off = _CACHE_HEAD_AXIS.get(name)
    if name in ("k_scale", "v_scale"):
        head_off = 1
    if head_off is not None and len(shape) >= head_off:
        res = rules.axes_for("kv_heads", shape[len(shape) - head_off])
        if res:
            dims[len(shape) - head_off] = res
    return P(*dims)


def place_prefix_snapshot(snap, rules: Rules):
    """Mesh placement for a prefix-cache snapshot (one slot's clustered
    summary rows, ``transformer.clustered_slot_state``).

    The snapshot's slot dim is 1 so it cannot shard over ``data`` — the
    B=1 admission argument applies (one device assignment per jit) — but
    kv-head dims shard over ``model`` exactly like the admission specs,
    so a pinned snapshot costs ``1/model``-th of a dense slot row per
    device.  Note the asymmetry with the blocks the snapshot rides with:
    physical block ids are meaningful ONLY on the data shard that owns
    them (``block_table_spec`` partitions tables by slot, and the
    shard_map island rebases ids per shard), so the host-side prefix
    maps are kept strictly per data shard and an admission can only
    adopt entries registered by slots of its own shard — the snapshot is
    the one piece that crosses shards, and only because it is
    slot-agnostic summary state."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(snap)
    placed = [
        jax.device_put(leaf, NamedSharding(
            rules.mesh, admission_spec(_leaf_path(kp), leaf.shape, rules)))
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, placed)


def place_swap_payload(payload, rules: Rules):
    """Mesh placement for a swapped-out slot's host round-trip at resume
    time (runtime/scheduler.py): the clustered snapshot plus the
    gathered tail-ring block payloads.

    Tail payload leaves are ``(n_mapped_blocks, block_size, H, Dh)``
    (or layer-stacked with one extra leading axis) — the leading block
    axis indexes the *specific* blocks being scattered back, which land
    on whatever data shard the resuming slot lives on, so it cannot
    shard over ``data`` (same one-device-assignment argument as the B=1
    admission path).  Head dims shard over ``model`` exactly like
    ``admission_spec``, so the resume transfer costs ``1/model``-th of
    the payload per device — and a resume may land on a *different*
    shard than the swap-out (the payload is slot- and shard-agnostic
    host bytes; only pool block ids are shard-local, and those are
    re-allocated at resume)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(payload)
    placed = [
        jax.device_put(leaf, NamedSharding(
            rules.mesh, admission_spec(_leaf_path(kp), leaf.shape, rules)))
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, placed)


def place_admission(cache, rules: Rules):
    """Place a B=1 admission-prefill cache on the mesh with
    ``admission_spec`` layouts (model-sharded heads, minimal replication)
    before the donated slot-write scatters it into the engine cache."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    placed = [
        jax.device_put(leaf, NamedSharding(
            rules.mesh, admission_spec(_leaf_path(kp), leaf.shape, rules)))
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, placed)


def _leaf_path(kp) -> str:
    return "/".join(_key_str(k) for k in kp)


def shard_cache(cache, rules: Rules):
    """Place a serving cache onto the rules' mesh (host side: engine init
    and post-compaction re-placement use ``jax.device_put``)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    placed = [
        jax.device_put(leaf, NamedSharding(
            rules.mesh, cache_spec(_leaf_path(kp), leaf.shape, rules)))
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, placed)


def constrain_cache(cache, rules: Rules):
    """``with_sharding_constraint`` twin of ``shard_cache`` for use inside
    traced functions (decode / slot-write outputs keep stable layouts)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [
        jax.lax.with_sharding_constraint(leaf, NamedSharding(
            rules.mesh, cache_spec(_leaf_path(kp), leaf.shape, rules)))
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_param_specs(params, rules: Rules):
    """PartitionSpec pytree for a parameter pytree (path-aware)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs.append(param_spec(path, leaf.shape, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


# serve-time placement: only the leaves whose replication cost dominates
# are distributed; everything else replicates (the serving engine's
# annotate/shard_map islands shard the COMPUTE, and small replicated
# weights keep every decode launch free of parameter collectives)
_SERVING_DISTRIBUTED = re.compile(r"moe/(w_gate|w_up|w_down)$")


def serving_param_specs(params, rules: Rules):
    """PartitionSpec pytree for serve-time parameter placement.

    MoE routed-expert banks — by far the largest leaves in an MoE config
    (Qwen2-MoE: 60 experts × (d, f) per projection per layer) — are
    placed by ``param_spec``, which puts the expert dim on the ``model``
    axis (spilling onto ``data`` when the count divides, prefix-falling
    back to ``model`` alone for awkward counts like 60 on a 4-wide
    axis).  Every other leaf replicates, exactly as serving always did:
    attention/MLP weights are small enough that replication beats the
    gather traffic GSPMD would synthesize into each decode step.  Pure
    placement — no cache change, no compute change (ROADMAP item 5)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs.append(param_spec(path, leaf.shape, rules)
                     if _SERVING_DISTRIBUTED.search(path) else P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def shardings_from_specs(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
