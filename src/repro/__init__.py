"""repro: bit-serial median clustering for memory management and request
processing — a multi-pod JAX training/serving framework."""
