"""Registry of assigned architectures (+ the paper's own clustering workload).

``get_config(arch_id)`` returns the full published config;
``get_reduced(arch_id)`` returns the family-preserving smoke-test config.
"""

from __future__ import annotations

from repro.configs import (
    codeqwen1_5_7b,
    deepseek_v3_671b,
    gemma2_27b,
    gemma3_4b,
    internvl2_76b,
    mamba2_2_7b,
    qwen2_moe_a2_7b,
    qwen3_4b,
    recurrentgemma_9b,
    seamless_m4t_medium,
)

_MODULES = {
    "internvl2-76b": internvl2_76b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "gemma2-27b": gemma2_27b,
    "gemma3-4b": gemma3_4b,
    "qwen3-4b": qwen3_4b,
    "mamba2-2.7b": mamba2_2_7b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "seamless-m4t-medium": seamless_m4t_medium,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str):
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str):
    return _MODULES[arch_id].reduced()
