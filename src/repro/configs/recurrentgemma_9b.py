"""recurrentgemma-9b [hybrid] — RecurrentGemma 9B (Griffin).

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000;
pattern: 2 RG-LRU recurrent blocks : 1 local attention (window 2048),
GeGLU, embed scaling [arXiv:2402.19427; unverified].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern="RRL",
    sliding_window=2048,
    mlp_kind="geglu",
    embed_scale=True,
    tie_embeddings=True,
    lru_width=4096,
    rope_theta=10000.0,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, sliding_window=8, lru_width=64,
    ).validate()
