"""gemma3-4b [dense] — Gemma 3 4B text backbone.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; 5:1 local:global,
window 1024, qk-norm, local rope theta 10k / global 1M, sandwich norms,
GeGLU, 128k context [hf:google/gemma-3-*-pt; unverified].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    layer_pattern="LLLLLG",
    sliding_window=1024,
    mlp_kind="geglu",
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, sliding_window=8,
    ).validate()
