"""mamba2-2.7b [ssm] — Mamba-2 2.7B (SSD, state-space duality).

64L d_model=2560, attention-free, ssm_state=128, expand 2 (d_inner 5120,
80 heads × head_dim 64), vocab 50280 (padded to 50304 for sharding)
[arXiv:2405.21060; unverified].
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,            # d_inner / head_dim
    n_kv_heads=80,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    layer_pattern="M",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        vocab=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1,
                      chunk=32),
    ).validate()
