"""qwen3-4b [dense] — Qwen3 4B.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936; qk-norm, head_dim
128 (decoupled from d_model) [hf:Qwen/Qwen3-*; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    layer_pattern="G",
    mlp_kind="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
    ).validate()
