"""internvl2-76b [vlm] — InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified].  The ViT frontend is a STUB: ``input_specs``
supplies precomputed patch embeddings prepended to the text sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    layer_pattern="G",
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    n_frontend_tokens=64,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512, n_frontend_tokens=4,
    ).validate()
