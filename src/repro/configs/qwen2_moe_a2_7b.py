"""qwen2-moe-a2.7b [moe] — Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=151936,
MoE: 4 shared + 60 routed, top-4  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].
Shared-expert hidden width 5632 (= 4×1408, the fused shared expert).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    layer_pattern="G",
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        n_routed=60, n_shared=1, top_k=4, d_expert=1408, d_shared=5632,
        router="softmax", norm_topk=False, aux_loss_coef=0.001,
    ),
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=256,
        moe=dataclasses.replace(CONFIG.moe, n_routed=8, top_k=2, d_expert=96,
                                d_shared=128),
    ).validate()
