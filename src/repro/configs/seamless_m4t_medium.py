"""seamless-m4t-medium [audio] — SeamlessM4T-medium text/speech backbone.

Encoder–decoder: 12L encoder + 12L decoder, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 (padded to 256256); LayerNorm, sinusoidal positions
[arXiv:2308.11596; hf].  The speech frontend is a STUB: ``input_specs``
supplies precomputed audio frame embeddings to the encoder.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,            # decoder depth
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    layer_pattern="G",
    mlp_kind="geglu",
    norm_kind="layernorm",
    pos_kind="abs_sinusoidal",
    tie_embeddings=True,
    frontend="audio_stub",
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256,
    ).validate()
