"""codeqwen1.5-7b [dense] — CodeQwen1.5-7B (qwen1.5 arch).

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    layer_pattern="G",
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512,
    ).validate()
