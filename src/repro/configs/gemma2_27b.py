"""gemma2-27b [dense] — Gemma 2 27B.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; alternating
local(4096)/global attention, attn logit softcap 50, final softcap 30,
pre+post sandwich norms, GeGLU, query scale (d_model/n_heads)^-0.5
[arXiv:2408.00118; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    layer_pattern="LG",
    sliding_window=4096,
    mlp_kind="geglu",
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=(4608 // 32) ** -0.5,
    rope_theta=10000.0,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, sliding_window=16,
        query_scale=(128 // 4) ** -0.5,
    ).validate()
