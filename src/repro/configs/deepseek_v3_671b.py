"""deepseek-v3-671b [moe] — DeepSeek-V3.

61L d_model=7168 128H MLA d_ff=2048 (per routed expert) vocab=129280,
MoE: 1 shared + 256 routed top-8, sigmoid router; MLA with kv_lora 512,
q_lora 1536, rope head 64; first 3 layers dense (d_ff 18432); MTP depth 1
[arXiv:2412.19437; hf].
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab=129280,
    layer_pattern="G",
    mlp_kind="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        n_routed=256, n_shared=1, top_k=8, d_expert=2048, d_shared=2048,
        router="sigmoid", norm_topk=True, aux_loss_coef=0.0001,
        n_dense_layers=3, d_ff_dense=18432,
        impl="a2a",  # 256 experts == 16×16 EP group → explicit all-to-all
    ),
    mtp_depth=1,
).validate()


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=64, vocab=256,
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        moe=dataclasses.replace(CONFIG.moe, n_routed=8, top_k=2, d_expert=64,
                                d_shared=64, n_dense_layers=1, d_ff_dense=128),
        mtp_depth=1,
    ).validate()
