from repro.data import pipeline  # noqa: F401
