"""Data pipeline: deterministic, stateless-seekable, host-sharded.

Two sources:
  * ``SyntheticLM`` — deterministic token streams (hash-mixed), so any step
    index reproduces its batch exactly — restart/elastic-resume safe.
  * ``TableDataset`` — the paper's tabular clustering workloads (wine-like
    quality table, census-like population table, Gaussian mixtures), used by
    the clustering benchmarks and examples.

The loader is *stateless*: ``batch_at(step)`` is a pure function of
(seed, step, host_id, n_hosts) — the fault-tolerance story (DESIGN §5)
depends on this: after a restart the trainer asks for step k and gets the
identical batch, and a re-shard to a different host count re-partitions
the same global batch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    host_id: int = 0
    n_hosts: int = 1


class SyntheticLM:
    """Deterministic pseudo-corpus with local n-gram structure (so a small
    model can actually learn and loss visibly decreases)."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        assert dc.global_batch % dc.n_hosts == 0
        self.local_batch = dc.global_batch // dc.n_hosts

    def _tokens_for(self, step: int, row: int, length: int) -> np.ndarray:
        seed = (self.dc.seed * 1_000_003 + step * 8191 + row) % (2**31 - 1)
        rng = np.random.default_rng(seed)
        v = self.cfg.vocab
        # Markov-ish stream: next token = (prev * a + noise) % v with
        # periodic resets — learnable local structure.
        a = 31 + (seed % 17)
        toks = np.zeros((length,), np.int32)
        toks[0] = rng.integers(0, v)
        noise = rng.integers(0, 7, size=(length,))
        for t in range(1, length):
            toks[t] = (toks[t - 1] * a + noise[t]) % v
        return toks

    def batch_at(self, step: int) -> dict:
        dc, cfg = self.dc, self.cfg
        s = dc.seq_len
        s_tok = s - (cfg.n_frontend_tokens if not cfg.is_encdec else s // 2)
        if cfg.is_encdec:
            s_tok = s // 2
        rows = []
        row0 = dc.host_id * self.local_batch
        for r in range(self.local_batch):
            rows.append(self._tokens_for(step, row0 + r, s_tok + 1))
        arr = np.stack(rows)
        batch = {"tokens": arr[:, :-1].astype(np.int32),
                 "labels": arr[:, 1:].astype(np.int32)}
        if cfg.is_encdec:
            rng = np.random.default_rng(dc.seed + step)
            batch["enc_embeds"] = rng.normal(
                size=(self.local_batch, s // 2, cfg.d_model)
            ).astype(np.float32) * 0.1
        elif cfg.n_frontend_tokens:
            rng = np.random.default_rng(dc.seed + step)
            batch["frontend_embeds"] = rng.normal(
                size=(self.local_batch, cfg.n_frontend_tokens, cfg.d_model)
            ).astype(np.float32) * 0.1
        return batch


# ---------------------------------------------------------------------------
# Paper-style tabular datasets (clustering benchmarks)
# ---------------------------------------------------------------------------

WINE_FEATURES = [
    "fixed_acidity", "volatile_acidity", "citric_acid", "residual_sugar",
    "chlorides", "free_sulfur_dioxide", "total_sulfur_dioxide", "density",
    "pH", "sulphates", "alcohol", "quality",
]


def wine_like(n: int = 4595, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic table matching the paper's §4 wine-quality statistics
    (means/ranges from the paper's summary table); labels = quality band."""
    rng = np.random.default_rng(seed)
    means = np.array([6.85, 0.275, 0.334, 6.39, 0.0458, 35.3, 138.4,
                      0.994, 3.19, 0.49, 10.5, 5.88], np.float64)
    stds = np.array([0.84, 0.10, 0.12, 5.07, 0.022, 17.0, 42.5,
                     0.003, 0.15, 0.11, 1.2, 0.87], np.float64)
    k = 3
    labels = rng.integers(0, k, size=(n,))
    shift = (labels[:, None] - 1) * stds[None, :] * 1.5
    x = rng.normal(size=(n, 12)) * stds[None, :] + means[None, :] + shift
    return x.astype(np.float32), labels.astype(np.int32)


def census_like(n: int = 5000, d: int = 8, seed: int = 1,
                outlier_frac: float = 0.01):
    """Census-style table (paper Table 1) with heavy-tailed outliers — the
    workload where median centroids beat means."""
    rng = np.random.default_rng(seed)
    k = 5
    centers = rng.normal(size=(k, d)) * 4.0
    labels = rng.integers(0, k, size=(n,))
    x = rng.normal(size=(n, d)) * 0.6 + centers[labels]
    n_out = int(n * outlier_frac)
    idx = rng.choice(n, n_out, replace=False)
    x[idx] += rng.normal(size=(n_out, d)) * 100.0
    return x.astype(np.float32), labels.astype(np.int32)


def gaussian_blobs(n_per: int, centers: np.ndarray, std: float = 0.4,
                   seed: int = 0):
    rng = np.random.default_rng(seed)
    k, d = centers.shape
    xs = np.concatenate([
        rng.normal(size=(n_per, d)) * std + centers[c] for c in range(k)])
    ys = np.repeat(np.arange(k, dtype=np.int32), n_per)
    perm = rng.permutation(len(xs))
    return xs[perm].astype(np.float32), ys[perm]


def uci_style_suite(seed: int = 0):
    """Synthetic stand-ins mirroring the paper's Table 3 datasets
    (Iris/Wine/Vowel/Ionosphere/Crude-oil: small labeled tables)."""
    rng = np.random.default_rng(seed)
    suite = {}
    specs = {
        "iris": (150, 4, 3, 2.5),
        "wine": (178, 13, 3, 1.6),
        "vowel": (871, 3, 6, 1.2),
        "ionosphere": (351, 34, 2, 1.1),
        "crude_oil": (56, 5, 3, 1.8),
    }
    for name, (n, d, k, sep) in specs.items():
        centers = rng.normal(size=(k, d)) * sep
        x, y = gaussian_blobs(max(n // k, 8), centers, std=1.0,
                              seed=seed + hash(name) % 1000)
        suite[name] = (x[:n], y[:n])
    return suite
