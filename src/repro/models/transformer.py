"""Model assembly: decoder-only / encoder–decoder stacks over the sub-layer
zoo (GQA global/local attention, MLA, MoE, Mamba2 SSD, RG-LRU), with
``lax.scan`` over homogeneous layer groups (compile time stays O(1) in
depth), remat for training, chunked cross-entropy (full logits are never
materialized), KV/state caches for serving, and DeepSeek-style MTP.

Layer layout: ``prefix`` (unrolled, e.g. DeepSeek's 3 dense layers) →
``scan`` (n_rep repeats of the layer_pattern group) → ``tail`` (pattern
remainder, unrolled).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import layer_state
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (apply_frontend, apply_mlp, apply_norm,
                                 cdtype, dense_init, embed_tokens,
                                 init_embed, init_frontend, init_mlp,
                                 init_norm, lm_logits, rng_for,
                                 sinusoidal_pos)
from repro.sharding import annotate


# ---------------------------------------------------------------------------
# Layer-count bookkeeping
# ---------------------------------------------------------------------------


def layout(cfg: ModelConfig):
    """(n_prefix, n_rep, tail_kinds) for the decoder stack."""
    n_prefix = cfg.moe.n_dense_layers if cfg.moe else 0
    rest = cfg.n_layers - n_prefix
    plen = len(cfg.layer_pattern)
    n_rep = rest // plen
    tail = [cfg.layer_pattern[i % plen] for i in range(n_rep * plen, rest)]
    return n_prefix, n_rep, tail


# ---------------------------------------------------------------------------
# Single sub-layer (params + apply in all three modes)
# ---------------------------------------------------------------------------


def init_sublayer(rng, cfg: ModelConfig, kind: str, use_moe: bool,
                  d_ff: Optional[int] = None, cross: bool = False):
    p = {"norm1": init_norm(rng, cfg, cfg.d_model)}
    if kind in ("G", "L"):
        if cfg.attn_kind == "mla":
            p["attn"] = attn.init_mla(rng_for(rng, "attn"), cfg)
        else:
            p["attn"] = attn.init_attn(rng_for(rng, "attn"), cfg)
        if cfg.post_norms:
            p["post_attn_norm"] = init_norm(rng, cfg, cfg.d_model)
        if cross:
            p["xnorm"] = init_norm(rng, cfg, cfg.d_model)
            p["xattn"] = attn.init_cross_attn(rng_for(rng, "xattn"), cfg)
        p["norm2"] = init_norm(rng, cfg, cfg.d_model)
        if use_moe:
            p["moe"] = moe_mod.init_moe(rng_for(rng, "moe"), cfg)
        else:
            p["mlp"] = init_mlp(rng_for(rng, "mlp"), cfg,
                                d_ff or cfg.d_ff)
        if cfg.post_norms:
            p["post_mlp_norm"] = init_norm(rng, cfg, cfg.d_model)
    elif kind == "M":
        p["ssm"] = ssm_mod.init_ssm(rng_for(rng, "ssm"), cfg)
    elif kind == "R":
        p["rg"] = rg_mod.init_rglru(rng_for(rng, "rg"), cfg)
        p["norm2"] = init_norm(rng, cfg, cfg.d_model)
        p["mlp"] = init_mlp(rng_for(rng, "mlp"), cfg, d_ff or cfg.d_ff)
    else:
        raise ValueError(kind)
    return p


def _ffn(p, h, cfg: ModelConfig):
    """norm2 → (moe|mlp) → residual (+sandwich norm).  Returns (h, aux)."""
    x = apply_norm(p["norm2"], h, cfg)
    if "moe" in p:
        y, metrics = moe_mod.apply_moe(p["moe"], x, cfg)
        aux = metrics["aux_loss"]
    else:
        y = apply_mlp(p["mlp"], x, cfg)
        aux = jnp.float32(0.0)
    if cfg.post_norms:
        y = apply_norm(p["post_mlp_norm"], y, cfg)
    return h + y, aux


def sublayer_train(p, h, cfg: ModelConfig, kind: str, *, positions,
                   kv_repeat: int, causal: bool = True, enc_kv=None):
    """Full-sequence forward. Returns (h, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("G", "L"):
        x = apply_norm(p["norm1"], h, cfg)
        if cfg.attn_kind == "mla":
            y = attn.mla_train(p["attn"], x, cfg, positions=positions)
        else:
            y = attn.attn_train(p["attn"], x, cfg, layer_kind=kind,
                                positions=positions, kv_repeat=kv_repeat,
                                causal=causal)
        if cfg.post_norms:
            y = apply_norm(p["post_attn_norm"], y, cfg)
        h = h + y
        if enc_kv is not None:
            x = apply_norm(p["xnorm"], h, cfg)
            h = h + attn.cross_attn_apply(p["xattn"], x, enc_kv, cfg)
        h, aux = _ffn(p, h, cfg)
    elif kind == "M":
        x = apply_norm(p["norm1"], h, cfg)
        h = h + ssm_mod.ssm_train(p["ssm"], x, cfg)
    elif kind == "R":
        x = apply_norm(p["norm1"], h, cfg)
        h = h + rg_mod.rglru_train(p["rg"], x, cfg)
        h, aux = _ffn(p, h, cfg)
    return h, aux


def init_sublayer_cache(cfg: ModelConfig, kind: str, batch: int,
                        max_seq: int, kv_repeat: int,
                        kv_mode: str = "exact", kv_clusters: int = 512,
                        kv_tail: int = 256, kv_pool_blocks: int = 0,
                        kv_block_size: int = 0):
    if kind in ("G", "L"):
        if cfg.attn_kind == "mla":
            return attn.init_cache_mla(cfg, batch, max_seq)
        if kind == "G" and kv_mode == "clustered":
            return attn.init_cache_attn_clustered(
                cfg, batch, n_clusters=kv_clusters, tail=kv_tail,
                kv_repeat=kv_repeat, pool_blocks=kv_pool_blocks,
                block_size=kv_block_size)
        return attn.init_cache_attn(cfg, kind, batch, max_seq, kv_repeat,
                                    quantized=(kv_mode == "int8"))
    if kind == "M":
        return ssm_mod.init_cache_ssm(cfg, batch)
    if kind == "R":
        return rg_mod.init_cache_rglru(cfg, batch)
    raise ValueError(kind)


def sublayer_prefill(p, h, cfg: ModelConfig, kind: str, *, positions,
                     kv_repeat: int, max_seq: int, enc_kv=None,
                     recurrent_mode: str = "scan"):
    """Returns (h, cache, aux).

    ``recurrent_mode`` selects how recurrent-state layers ('M'/'R')
    compute the prefill: "scan" (default) uses the parallel forms —
    chunked SSD / log-depth associative scan — which are mathematically
    exact but not *bitwise* equal to stepping the one-token decode;
    "sequential" steps the decode recurrence position by position, so a
    prefill is bit-identical to feeding the prompt through the decode
    path one token at a time.  The serving engine uses "sequential":
    its chunked admission advances recurrent state token-by-token inside
    the mixed launch, and blocking admission must match it bitwise.
    """
    aux = jnp.float32(0.0)
    if kind in ("G", "L"):
        x = apply_norm(p["norm1"], h, cfg)
        if cfg.attn_kind == "mla":
            y, cache = attn.mla_prefill(p["attn"], x, cfg,
                                        positions=positions, max_seq=max_seq)
        else:
            y, cache = attn.attn_prefill(p["attn"], x, cfg, layer_kind=kind,
                                         positions=positions,
                                         kv_repeat=kv_repeat)
            # pad non-window caches out to max_seq for decode
            if cache["k"].shape[1] < max_seq and kind == "G":
                padn = max_seq - cache["k"].shape[1]
                cache = {
                    "k": jnp.pad(cache["k"],
                                 ((0, 0), (0, padn), (0, 0), (0, 0))),
                    "v": jnp.pad(cache["v"],
                                 ((0, 0), (0, padn), (0, 0), (0, 0))),
                }
        if cfg.post_norms:
            y = apply_norm(p["post_attn_norm"], y, cfg)
        h = h + y
        if enc_kv is not None:
            x = apply_norm(p["xnorm"], h, cfg)
            h = h + attn.cross_attn_apply(p["xattn"], x, enc_kv, cfg)
        h, aux = _ffn(p, h, cfg)
        return h, cache, aux
    if kind == "M":
        # prefill == train pass + terminal state via the sequential tail:
        # run chunked SSD for outputs; rebuild the state with a short
        # decode burn-in is wasteful, so recompute final state directly.
        x = apply_norm(p["norm1"], h, cfg)
        if recurrent_mode == "sequential":
            y, cache = _recurrent_prefill_sequential(
                lambda xt, c: ssm_mod.ssm_decode(p["ssm"], xt, cfg, c),
                x, ssm_mod.init_cache_ssm(cfg, x.shape[0]))
        else:
            y, cache = _ssm_prefill(p["ssm"], x, cfg)
        return h + y, cache, aux
    if kind == "R":
        x = apply_norm(p["norm1"], h, cfg)
        if recurrent_mode == "sequential":
            y, cache = _recurrent_prefill_sequential(
                lambda xt, c: rg_mod.rglru_decode(p["rg"], xt, cfg, c),
                x, rg_mod.init_cache_rglru(cfg, x.shape[0]))
        else:
            y, cache = rg_mod.rglru_prefill(p["rg"], x, cfg)
        h = h + y
        h, aux = _ffn(p, h, cfg)
        return h, cache, aux
    raise ValueError(kind)


def _recurrent_prefill_sequential(step_fn, x, cache):
    """Prefill a recurrent layer by stepping its one-token decode.

    x (B, S, d) normed input; ``step_fn(xt (B,1,d), cache) -> (y, cache)``
    is the layer's decode recurrence.  Returns (y (B, S, d), cache) that
    is bit-identical — not just numerically close — to feeding the S
    positions through the decode path one at a time, which is what the
    chunked serving engine's mixed launch does.
    """

    def step(c, xt):
        y, c = step_fn(xt[:, None, :], c)
        return c, y[:, 0]

    cache, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), cache


def _ssm_prefill(p, x, cfg: ModelConfig):
    """Chunked SSD forward + final (conv, ssm) state for decode."""
    s_cfg = cfg.ssm
    dt_ = cdtype(cfg)
    d_in, hh, conv_ch = ssm_mod._dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state
    z, xbc_raw, dt_raw = ssm_mod._split(p, x, cfg)
    xbc = ssm_mod._conv_train(p, xbc_raw, cfg)
    b, s, _ = x.shape
    xh = xbc[..., :d_in].reshape(b, s, hh, s_cfg.head_dim)
    Bm = xbc[..., d_in:d_in + gn].reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    Cm = xbc[..., d_in + gn:].reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssm_mod.ssd_chunked(xh, dt, A, Bm, Cm, p["D"],
                                         s_cfg.chunk)
    y = y.reshape(b, s, d_in).astype(dt_)
    gated = y * jax.nn.silu(z)
    var = (gated.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["norm"]).astype(dt_)
    out = gated @ p["out_proj"].astype(dt_)
    conv_tail = (xbc_raw[:, -(s_cfg.d_conv - 1):]
                 if s >= s_cfg.d_conv - 1 else
                 jnp.pad(xbc_raw, ((0, 0), (s_cfg.d_conv - 1 - s, 0), (0, 0))))
    cache = {"conv": conv_tail.astype(dt_), "ssm": final_state}
    return out, cache


def sublayer_decode(p, h, cfg: ModelConfig, kind: str, cache, t, *,
                    kv_repeat: int, enc_kv=None, chunk_len=None):
    """h (B,1,d) — or (B,L,d) mixed-mode with per-slot ``chunk_len``
    (chunked prefill interleaved with decode).  Ring-family layers
    stream the chunk into their KV at exact positions; recurrent-state
    layers ('M'/'R') advance their fixed-size state column by column
    with per-slot masking (:func:`_recurrent_mixed_advance`).
    Returns (h, cache')."""
    if kind in ("G", "L"):
        x = apply_norm(p["norm1"], h, cfg)
        if cfg.attn_kind == "mla":
            if chunk_len is not None:
                raise NotImplementedError(
                    "mixed-mode chunked decode is not wired for MLA "
                    "latent caches yet")
            y, cache = attn.mla_decode(p["attn"], x, cfg, cache=cache, t=t)
        else:
            y, cache = attn.attn_decode(p["attn"], x, cfg, layer_kind=kind,
                                        cache=cache, t=t,
                                        kv_repeat=kv_repeat,
                                        chunk_len=chunk_len)
        if cfg.post_norms:
            y = apply_norm(p["post_attn_norm"], y, cfg)
        h = h + y
        if enc_kv is not None:
            x = apply_norm(p["xnorm"], h, cfg)
            h = h + attn.cross_attn_apply(p["xattn"], x, enc_kv, cfg)
        h, _ = _ffn(p, h, cfg)
        return h, cache
    if kind == "M":
        x = apply_norm(p["norm1"], h, cfg)
        if chunk_len is None:
            y, cache = ssm_mod.ssm_decode(p["ssm"], x, cfg, cache)
        else:
            y, cache = _recurrent_mixed_advance(
                lambda xt, c: ssm_mod.ssm_decode(p["ssm"], xt, cfg, c),
                x, cache, chunk_len)
        return h + y, cache
    if kind == "R":
        x = apply_norm(p["norm1"], h, cfg)
        if chunk_len is None:
            y, cache = rg_mod.rglru_decode(p["rg"], x, cfg, cache)
        else:
            y, cache = _recurrent_mixed_advance(
                lambda xt, c: rg_mod.rglru_decode(p["rg"], xt, cfg, c),
                x, cache, chunk_len)
        h = h + y
        h, _ = _ffn(p, h, cfg)
        return h, cache
    raise ValueError(kind)


def _recurrent_mixed_advance(step_fn, x, cache, chunk_len):
    """Advance recurrent state through a mixed prefill+decode launch.

    x (B, L, d) normed chunk columns; chunk_len (B,) valid columns per
    slot (decode slots carry 1).  Scans the L columns through the
    layer's one-token decode ``step_fn``, masking each slot's state
    update once its chunk is exhausted — so every slot's state advances
    by exactly its own tokens, in order, with per-step ops identical to
    the blocking decode path (bitwise-equal states by construction).
    Columns at/after chunk_len produce garbage outputs that the caller's
    last-valid-row gather never reads.
    """
    b, L, _ = x.shape
    cl = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))

    def col(c, xs):
        xt, i = xs
        y, c_new = step_fn(xt[:, None, :], c)            # (B, 1, d)
        keep = i < cl                                    # (B,)
        c = jax.tree.map(
            lambda new, old: jnp.where(
                keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
            c_new, c)
        return c, y[:, 0]

    cache, ys = jax.lax.scan(col, cache,
                             (x.transpose(1, 0, 2), jnp.arange(L)))
    return ys.transpose(1, 0, 2), cache


# ---------------------------------------------------------------------------
# Full-model params
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig):
    n_prefix, n_rep, tail = layout(cfg)
    use_moe = cfg.moe is not None
    p = {"embed": init_embed(rng_for(rng, "embed"), cfg)}
    fe = init_frontend(rng_for(rng, "frontend"), cfg)
    if fe is not None:
        p["frontend"] = fe

    cross = cfg.is_encdec
    p["prefix"] = [
        init_sublayer(rng_for(rng, f"prefix{i}"), cfg, "G", False,
                      d_ff=cfg.moe.d_ff_dense if cfg.moe else None,
                      cross=cross)
        for i in range(n_prefix)
    ]

    def group_init(r):
        return {
            f"sub{j}": init_sublayer(
                jax.random.fold_in(r, j), cfg, cfg.layer_pattern[j],
                use_moe and cfg.layer_pattern[j] in "GL", cross=cross)
            for j in range(len(cfg.layer_pattern))
        }

    if n_rep > 0:
        p["scan"] = jax.vmap(group_init)(
            jax.random.split(rng_for(rng, "scan"), n_rep))
    p["tail"] = [
        init_sublayer(rng_for(rng, f"tail{i}"), cfg, k,
                      use_moe and k in "GL", cross=cross)
        for i, k in enumerate(tail)
    ]
    p["final_norm"] = init_norm(rng, cfg, cfg.d_model)

    if cfg.is_encdec:
        enc = {}
        enc["scan"] = jax.vmap(
            lambda r: {"sub0": init_sublayer(r, cfg, "G", False)})(
                jax.random.split(rng_for(rng, "enc"), cfg.enc_layers))
        enc["final_norm"] = init_norm(rng, cfg, cfg.d_model)
        p["encoder"] = enc

    if cfg.mtp_depth > 0:
        p["mtp"] = {
            "proj": dense_init(rng_for(rng, "mtp/proj"),
                               (2 * cfg.d_model, cfg.d_model)),
            "norm_h": init_norm(rng, cfg, cfg.d_model),
            "norm_e": init_norm(rng, cfg, cfg.d_model),
            "layer": init_sublayer(rng_for(rng, "mtp/layer"), cfg, "G",
                                   use_moe),
            "final_norm": init_norm(rng, cfg, cfg.d_model),
        }
    return p


# ---------------------------------------------------------------------------
# Trunk forward (training)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend_embeds):
    h = embed_tokens(params["embed"], tokens, cfg)
    if frontend_embeds is not None:
        fe = apply_frontend(params["frontend"], frontend_embeds, cfg)
        h = jnp.concatenate([fe, h], axis=1)
    if cfg.pos_kind == "abs_sinusoidal":
        h = h + sinusoidal_pos(h.shape[1], cfg.d_model).astype(h.dtype)[None]
    return annotate(h, "batch", "seq", "d_model")


def encode(params, cfg: ModelConfig, enc_embeds):
    """Encoder stack over stub frame embeddings (B, S_enc, d)."""
    enc = params["encoder"]
    h = apply_frontend(params["frontend"], enc_embeds, cfg)
    if cfg.pos_kind == "abs_sinusoidal":
        h = h + sinusoidal_pos(h.shape[1], cfg.d_model).astype(h.dtype)[None]
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        hh, _ = sublayer_train(lp["sub0"], hh, cfg, "G", positions=positions,
                               kv_repeat=1, causal=False)
        return hh, None

    h, _ = jax.lax.scan(body, h, enc["scan"])
    return apply_norm(enc["final_norm"], h, cfg)


def forward_trunk(params, cfg: ModelConfig, tokens, *, frontend_embeds=None,
                  enc_out=None, kv_repeat: int = 1, remat: bool = True,
                  positions=None):
    """Returns (h (B, S, d), aux_loss_sum)."""
    h = _embed_inputs(params, cfg, tokens, frontend_embeds)
    if positions is None:
        positions = jnp.arange(h.shape[1])
    enc_kv = None

    aux_total = jnp.float32(0.0)

    def run(p, h, kind, ekv):
        return sublayer_train(p, h, cfg, kind, positions=positions,
                              kv_repeat=kv_repeat, enc_kv=ekv)

    for i, lp in enumerate(params["prefix"]):
        ekv = _layer_enc_kv(lp, enc_out, cfg)
        h, aux = run(lp, h, "G", ekv)
        aux_total += aux

    if "scan" in params:
        def group_body(carry, lp):
            hh, aux_sum = carry
            for j, kind in enumerate(cfg.layer_pattern):
                ekv = _layer_enc_kv(lp[f"sub{j}"], enc_out, cfg)
                hh, aux = sublayer_train(lp[f"sub{j}"], hh, cfg, kind,
                                         positions=positions,
                                         kv_repeat=kv_repeat, enc_kv=ekv)
                aux_sum = aux_sum + aux
            return (hh, aux_sum), None

        body = jax.checkpoint(group_body) if remat else group_body
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), params["scan"])

    _, _, tail = layout(cfg)
    for lp, kind in zip(params["tail"], tail):
        ekv = _layer_enc_kv(lp, enc_out, cfg)
        h, aux = run(lp, h, kind, ekv)
        aux_total += aux

    h = apply_norm(params["final_norm"], h, cfg)
    return h, aux_total


def _layer_enc_kv(lp, enc_out, cfg):
    if enc_out is None or "xattn" not in lp:
        return None
    return attn.cross_kv(lp["xattn"], enc_out, cfg)


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy; logits never materialized over full S)
# ---------------------------------------------------------------------------


def chunked_ce(params, cfg: ModelConfig, h, labels, chunk: int = 256):
    """h (B, S, d), labels (B, S) int32 (−1 = masked) → (sum_nll, n_valid).
    Frontend positions (if any) must already be stripped from h."""
    b, s, _ = h.shape
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = h.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        nll, nv = carry
        hh, ll = xs
        logits = lm_logits(params["embed"], hh, cfg)     # (B, C, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        valid = (ll >= 0).astype(jnp.float32)
        nll = nll + ((logz - gold) * valid).sum()
        nv = nv + valid.sum()
        return (nll, nv), None

    (nll, nv), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                (hc, lc))
    return nll, nv


def train_loss(params, cfg: ModelConfig, batch, *, kv_repeat: int = 1,
               remat: bool = True, loss_chunk: int = 256):
    """batch: {tokens (B,St), labels (B,St), frontend_embeds?, enc_embeds?}.
    Returns (loss, metrics)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"])
        h, aux = forward_trunk(params, cfg, batch["tokens"], enc_out=enc_out,
                               kv_repeat=kv_repeat, remat=remat)
    else:
        h, aux = forward_trunk(params, cfg, batch["tokens"],
                               frontend_embeds=batch.get("frontend_embeds"),
                               kv_repeat=kv_repeat, remat=remat)
    if cfg.n_frontend_tokens and not cfg.is_encdec:
        h = h[:, cfg.n_frontend_tokens:]
    nll, nv = chunked_ce(params, cfg, h, batch["labels"], loss_chunk)
    loss = nll / jnp.maximum(nv, 1.0)
    metrics = {"nll": loss, "aux_loss": aux, "n_valid": nv}

    if cfg.mtp_depth > 0:
        mtp_loss = _mtp_loss(params, cfg, h, batch, kv_repeat, loss_chunk)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    loss = loss + aux
    return loss, metrics


def _mtp_loss(params, cfg: ModelConfig, h, batch, kv_repeat, loss_chunk):
    """DeepSeek MTP depth-1: predict token t+2 from (h_t, emb(token_{t+1}))."""
    mtp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    h_in = apply_norm(mtp["norm_h"], h[:, :-1], cfg)
    e_in = apply_norm(mtp["norm_e"],
                      embed_tokens(params["embed"], tokens[:, 1:], cfg), cfg)
    x = jnp.concatenate([h_in, e_in], axis=-1) @ mtp["proj"].astype(
        cdtype(cfg))
    positions = jnp.arange(x.shape[1])
    x, _ = sublayer_train(mtp["layer"], x, cfg, "G", positions=positions,
                          kv_repeat=kv_repeat)
    x = apply_norm(mtp["final_norm"], x, cfg)
    # position t predicts labels[t+1] (i.e. token t+2); length S-1 matches x
    mtp_labels = labels[:, 1:]
    nll, nv = chunked_ce(params, cfg, x, mtp_labels, loss_chunk)
    return nll / jnp.maximum(nv, 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def _all_kinds(cfg: ModelConfig):
    n_prefix, n_rep, tail = layout(cfg)
    return n_prefix, n_rep, tail


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               kv_repeat: int = 1, kv_mode: str = "exact",
               kv_clusters: int = 512, kv_tail: int = 256,
               kv_pool_blocks: int = 0, kv_block_size: int = 0):
    """``kv_pool_blocks``/``kv_block_size`` switch clustered tails to the
    paged block-pool layout (see runtime/kv_pool.py); one pool per layer
    leaf (scan-stacked leaves carry the layer dim), sharing the engine's
    single block table."""
    n_prefix, n_rep, tail = layout(cfg)
    mk = lambda kind: init_sublayer_cache(  # noqa: E731
        cfg, kind, batch, max_seq, kv_repeat, kv_mode, kv_clusters, kv_tail,
        kv_pool_blocks, kv_block_size)
    cache = {
        "prefix": [mk("G") for _ in range(n_prefix)],
        "tail": [mk(k) for k in tail],
    }
    if n_rep > 0:
        group = {f"sub{j}": mk(cfg.layer_pattern[j])
                 for j in range(len(cfg.layer_pattern))}
        cache["scan"] = jax.tree.map(
            lambda l: jnp.zeros((n_rep,) + l.shape, l.dtype), group)
    return cache


def clustered_slot_state(cache, j):
    """Snapshot slot ``j``'s per-slot state from every snapshot-bearing
    leaf of an engine cache:

    * clustered ring leaves — the summary rows (centroids, counts,
      coverage frontier; attention.CLUSTERED_SLOT_KEYS).  Tail payloads
      are NOT copied: in the paged engine they live in shared pool
      blocks that the prefix cache pins by ref count instead.
    * recurrent-state leaves ('M'/'R': {"conv","ssm"} / {"conv","h"}) —
      the *whole* fixed-size state.  For the recurrent family the state
      IS the checkpoint, so template-store prefix sharing and the
      preempt→swap→resume path carry it in this same snapshot format.

    Returns a cache-shaped pytree (other leaves dropped to None) that
    ``restore_clustered_slot_state`` writes back into any slot."""
    def leaf(node):
        stacked = node["k_cents"].ndim == 5       # scan: (L, B, ...)
        ax = 1 if stacked else 0
        return {k: jax.lax.dynamic_slice_in_dim(node[k], j, 1, axis=ax)
                for k in attn.CLUSTERED_SLOT_KEYS}

    def rleaf(node):
        ax = 1 if layer_state.recurrent_leaf_stacked(node) else 0
        return {k: jax.lax.dynamic_slice_in_dim(node[k], j, 1, axis=ax)
                for k in node}

    def walk(node):
        if isinstance(node, dict):
            if "k_cents" in node:
                return leaf(node)
            if layer_state.is_recurrent_leaf(node):
                return rleaf(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return None

    return walk(cache)


def restore_clustered_slot_state(cache, snap, j):
    """Write a ``clustered_slot_state`` snapshot into slot ``j`` of every
    snapshot-bearing leaf (prefix-sharing admission and swap-in resume:
    the reused prompt centroids + coverage frontier — and, for
    recurrent-state layers, the full (conv, ssm)/(conv, h) checkpoint —
    land in the fresh slot; ring tail blocks are adopted through the
    block table separately)."""
    def walk(node, s):
        if isinstance(node, dict):
            if "k_cents" in node:
                stacked = node["k_cents"].ndim == 5
                ax = 1 if stacked else 0
                return dict(node, **{
                    k: jax.lax.dynamic_update_slice_in_dim(
                        node[k], s[k].astype(node[k].dtype), j, axis=ax)
                    for k in attn.CLUSTERED_SLOT_KEYS})
            if layer_state.is_recurrent_leaf(node):
                ax = 1 if layer_state.recurrent_leaf_stacked(node) else 0
                return dict(node, **{
                    k: jax.lax.dynamic_update_slice_in_dim(
                        node[k], s[k].astype(node[k].dtype), j, axis=ax)
                    for k in node})
            return {k: walk(v, s[k]) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, sv) for v, sv in zip(node, s)]
        return node

    return walk(cache, snap)


def prefill(params, cfg: ModelConfig, tokens, *, max_seq: int,
            frontend_embeds=None, enc_embeds=None, kv_repeat: int = 1,
            last_pos=None, recurrent_mode: str = "scan"):
    """Full-sequence prefill.  Returns (last_logits (B, V), cache).

    ``last_pos`` (traced scalar ok) selects which position's logits to
    return — needed when prompts are right-padded to a bucket length (the
    continuous batcher): the causal mask makes position last_pos exact
    regardless of the padding behind it.

    ``recurrent_mode`` (see :func:`sublayer_prefill`): the serving
    engine passes "sequential" so recurrent-state layers prefill by
    stepping their decode recurrence — bit-identical to chunked
    admission through the mixed launch; "scan" keeps the parallel
    chunked-SSD / associative-scan forms for training-style use."""
    enc_out = None
    cross_cache = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, enc_embeds)
    h = _embed_inputs(params, cfg, tokens, frontend_embeds)
    positions = jnp.arange(h.shape[1])

    caches = {"prefix": [], "tail": []}
    cross = {"prefix": [], "tail": []}
    for lp in params["prefix"]:
        ekv = _layer_enc_kv(lp, enc_out, cfg)
        h, c, _ = sublayer_prefill(lp, h, cfg, "G", positions=positions,
                                   kv_repeat=kv_repeat, max_seq=max_seq,
                                   enc_kv=ekv, recurrent_mode=recurrent_mode)
        caches["prefix"].append(c)
        cross["prefix"].append(ekv)

    if "scan" in params:
        def group_body(hh, lp):
            cs = {}
            for j, kind in enumerate(cfg.layer_pattern):
                ekv = _layer_enc_kv(lp[f"sub{j}"], enc_out, cfg)
                hh, c, _ = sublayer_prefill(
                    lp[f"sub{j}"], hh, cfg, kind, positions=positions,
                    kv_repeat=kv_repeat, max_seq=max_seq, enc_kv=ekv,
                    recurrent_mode=recurrent_mode)
                cs[f"sub{j}"] = c
                if ekv is not None:
                    cs[f"xkv{j}"] = ekv
            return hh, cs

        h, scan_caches = jax.lax.scan(group_body, h, params["scan"])
        caches["scan"] = scan_caches

    _, _, tail = layout(cfg)
    for lp, kind in zip(params["tail"], tail):
        ekv = _layer_enc_kv(lp, enc_out, cfg)
        h, c, _ = sublayer_prefill(lp, h, cfg, kind, positions=positions,
                                   kv_repeat=kv_repeat, max_seq=max_seq,
                                   enc_kv=ekv, recurrent_mode=recurrent_mode)
        caches["tail"].append(c)
        cross["tail"].append(ekv)

    if cfg.is_encdec:
        caches["cross_prefix"] = [c for c in cross["prefix"]]
        caches["cross_tail"] = [c for c in cross["tail"]]

    h = apply_norm(params["final_norm"], h, cfg)
    h_last = (h[:, -1:] if last_pos is None
              else jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1))
    logits = lm_logits(params["embed"], h_last, cfg)[:, 0]
    return logits, caches


def decode_step(params, cfg: ModelConfig, cache, tokens, t, *,
                kv_repeat: int = 1, chunk_len=None):
    """One decode step.  tokens (B, 1), t scalar int32 (current position).
    Returns (logits (B, V), cache').

    Mixed mode (chunked prefill interleaved with decode): tokens (B, L)
    with per-slot ``chunk_len`` (B,) valid columns and ``t`` (B,) the
    slot's cache length before the step.  Decode slots carry their one
    pending token (chunk_len 1); a slot admitting a prompt carries a
    whole chunk whose K/V stream straight into its cache at exact
    positions t..t+chunk_len-1.  The returned logits are each slot's LAST
    valid row — the next-token distribution for decode slots, and the
    first-generated-token distribution when a slot's final prompt chunk
    lands.  Covers both layer-state families (ring-KV attention and
    'M'/'R' recurrent state); MLA latent caches and encoder-decoder
    remain unsupported."""
    if chunk_len is not None and cfg.is_encdec:
        raise NotImplementedError("mixed-mode chunked decode is "
                                  "decoder-only")
    h = embed_tokens(params["embed"], tokens, cfg)
    if cfg.embed_scale:
        pass  # already applied in embed_tokens
    if cfg.pos_kind == "abs_sinusoidal":
        # t may be scalar or per-slot (B,) under continuous batching
        tb = jnp.broadcast_to(jnp.asarray(t), (h.shape[0],))
        pe = jax.vmap(lambda ti: sinusoidal_pos(h.shape[1], cfg.d_model,
                                                offset=ti))(tb)   # (B, L, d)
        h = h + pe.astype(h.dtype)
    h = annotate(h, "batch", "seq", "d_model")

    new_cache = {"prefix": [], "tail": []}
    for lp, c in zip(params["prefix"], cache["prefix"]):
        ekv = cache.get("cross_prefix", [None] * len(params["prefix"]))
        h, c2 = sublayer_decode(lp, h, cfg, "G", c, t, kv_repeat=kv_repeat,
                                enc_kv=ekv[len(new_cache["prefix"])]
                                if cfg.is_encdec else None,
                                chunk_len=chunk_len)
        new_cache["prefix"].append(c2)

    if "scan" in params:
        def group_body(hh, xs):
            lp, cs = xs
            cs2 = dict(cs)
            for j, kind in enumerate(cfg.layer_pattern):
                ekv = cs.get(f"xkv{j}")
                hh, cnew = sublayer_decode(lp[f"sub{j}"], hh, cfg, kind,
                                           cs[f"sub{j}"], t,
                                           kv_repeat=kv_repeat, enc_kv=ekv,
                                           chunk_len=chunk_len)
                cs2[f"sub{j}"] = cnew
            return hh, cs2

        h, scan_caches = jax.lax.scan(group_body, h,
                                      (params["scan"], cache["scan"]))
        new_cache["scan"] = scan_caches

    _, _, tail = layout(cfg)
    for i, (lp, kind) in enumerate(zip(params["tail"], tail)):
        ekv = (cache.get("cross_tail", [None] * len(tail))[i]
               if cfg.is_encdec else None)
        h, c2 = sublayer_decode(lp, h, cfg, kind, cache["tail"][i], t,
                                kv_repeat=kv_repeat, enc_kv=ekv,
                                chunk_len=chunk_len)
        new_cache["tail"].append(c2)

    if cfg.is_encdec:
        new_cache["cross_prefix"] = cache["cross_prefix"]
        new_cache["cross_tail"] = cache["cross_tail"]

    h = apply_norm(params["final_norm"], h, cfg)
    if chunk_len is not None:
        # each slot's last valid row carries its next-token distribution;
        # gather before the vocab projection so the L× logits are never
        # materialized
        idx = (jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32),
                                (h.shape[0],)) - 1)[:, None, None]
        h = jnp.take_along_axis(h, idx, axis=1)
    logits = lm_logits(params["embed"], h, cfg)[:, 0]
    return logits, new_cache


def _sublayer_decode_packed(p, h, cfg: ModelConfig, cache, *, row_slot,
                            row_pos, row_tw, block_tables, block_size,
                            kv_repeat):
    """One 'G' sublayer over packed rows (paged clustered KV).  h
    (N, 1, d); every non-attention op is row-wise, so rows stand in for
    the batch axis exactly."""
    x = apply_norm(p["norm1"], h, cfg)
    y, cache = attn.attn_decode_clustered_packed(
        p["attn"], x, cfg, cache=cache, row_slot=row_slot, row_pos=row_pos,
        row_tw=row_tw, block_tables=block_tables, block_size=block_size,
        kv_repeat=kv_repeat)
    if cfg.post_norms:
        y = apply_norm(p["post_attn_norm"], y, cfg)
    h = h + y
    h, _ = _ffn(p, h, cfg)
    return h, cache


def _sublayer_decode_window_packed(p, h, cfg: ModelConfig, cache, *,
                                   row_slot, row_pos, row_cidx, width,
                                   kv_repeat):
    """One 'L' sublayer over packed rows: WindowRetention's dense ring,
    written in row_cidx order (attention.attn_decode_window_packed)."""
    x = apply_norm(p["norm1"], h, cfg)
    y, cache = attn.attn_decode_window_packed(
        p["attn"], x, cfg, cache=cache, row_slot=row_slot, row_pos=row_pos,
        row_cidx=row_cidx, width=width, kv_repeat=kv_repeat)
    if cfg.post_norms:
        y = apply_norm(p["post_attn_norm"], y, cfg)
    h = h + y
    h, _ = _ffn(p, h, cfg)
    return h, cache


def _sublayer_decode_recurrent_packed(p, h, cfg: ModelConfig, cache, kind,
                                      *, row_slot, row_pos, row_cidx,
                                      width):
    """One recurrent sublayer ('M'/'R') over packed rows.

    Recurrent state is slot-indexed and fixed-size, and must advance one
    token at a time in position order.  A slot's rows within a packed
    step carry distinct chunk indices (row_cidx 0..chunk_len-1), so the
    ``width`` rounds of this loop sequence them exactly: round ``jj``
    gathers every row's current slot state, steps all rows through the
    one-token decode, and scatters back only rows with cidx == jj (at
    most one row per slot per round → conflict-free).  Per-row math is
    batch-independent, so each round is bit-identical to the dense
    one-token decode; padding rows (row_pos < 0) never scatter.
    """
    x = apply_norm(p["norm1"], h, cfg)                   # (N, 1, d)
    decode = ssm_mod.ssm_decode if kind == "M" else rg_mod.rglru_decode
    pp = p["ssm"] if kind == "M" else p["rg"]
    n_slots = cache["conv"].shape[0]
    y = jnp.zeros_like(h)
    for jj in range(width):
        sel = (row_cidx == jj) & (row_pos >= 0)          # (N,)
        st = jax.tree.map(lambda a: a[row_slot], cache)
        y_j, st_new = decode(pp, x, cfg, st)
        idx = jnp.where(sel, row_slot, n_slots)
        cache = jax.tree.map(
            lambda a, nr: a.at[idx].set(nr.astype(a.dtype), mode="drop"),
            cache, st_new)
        y = jnp.where(sel[:, None, None], y_j.astype(y.dtype), y)
    h = h + y
    if kind == "R":
        h, _ = _ffn(p, h, cfg)
    return h, cache


def decode_step_packed(params, cfg: ModelConfig, cache, tokens, row_slot,
                       row_pos, row_tw, row_cidx, block_tables, *,
                       block_size: int, width: int = 1,
                       kv_repeat: int = 1):
    """Packed ragged engine step for the paged clustered-KV path.

    Instead of the dense launch's (slots, width) token grid — every slot
    paying ``width`` rows of trunk compute — each *real* (slot, position)
    pair is one row: tokens (N,), row_slot (N,) physical slot, row_pos
    (N,) absolute position (−1 ⇒ padding row), row_tw (N,) the slot's
    ring watermark t + chunk_len this step, row_cidx (N,) the row's index
    within its admission chunk (decode rows 0; ``width`` = static max
    chunk length, sequencing sliding-window ring commits), block_tables
    (B, T) global physical tail-block ids.  Returns (logits (N, V),
    cache'): every row's next-token distribution — the engine reads each
    slot's last valid row (decode slots: their one row; an admitting
    slot's final chunk row carries its first generated token).
    Decoder-only models whose layers all carry a layer-state family
    ('G' clustered/quota + 'L' sliding-window rings, 'M'/'R' recurrent
    state — the paged engine's gate); MLP / norms / embeddings are
    position-independent, so treating rows as batch is exact, and
    per-row outputs are bit-identical to the dense launch."""
    tokens = jnp.where(row_pos >= 0, tokens, 0)[:, None]   # (N, 1)
    h = embed_tokens(params["embed"], tokens, cfg)
    if cfg.pos_kind == "abs_sinusoidal":
        pe = jax.vmap(lambda ti: sinusoidal_pos(1, cfg.d_model,
                                                offset=ti))(row_pos)
        h = h + pe.astype(h.dtype)
    h = annotate(h, "batch", "seq", "d_model")

    def step(p, hh, c, kind):
        if kind in ("M", "R"):
            return _sublayer_decode_recurrent_packed(
                p, hh, cfg, c, kind, row_slot=row_slot, row_pos=row_pos,
                row_cidx=row_cidx, width=width)
        if kind == "L":
            return _sublayer_decode_window_packed(
                p, hh, cfg, c, row_slot=row_slot, row_pos=row_pos,
                row_cidx=row_cidx, width=width, kv_repeat=kv_repeat)
        return _sublayer_decode_packed(
            p, hh, cfg, c, row_slot=row_slot, row_pos=row_pos,
            row_tw=row_tw, block_tables=block_tables,
            block_size=block_size, kv_repeat=kv_repeat)

    new_cache = {"prefix": [], "tail": []}
    for lp, c in zip(params["prefix"], cache["prefix"]):
        h, c2 = step(lp, h, c, "G")
        new_cache["prefix"].append(c2)

    if "scan" in params:
        def group_body(hh, xs):
            lp, cs = xs
            cs2 = dict(cs)
            for j, kind in enumerate(cfg.layer_pattern):
                hh, cnew = step(lp[f"sub{j}"], hh, cs[f"sub{j}"], kind)
                cs2[f"sub{j}"] = cnew
            return hh, cs2

        h, scan_caches = jax.lax.scan(group_body, h,
                                      (params["scan"], cache["scan"]))
        new_cache["scan"] = scan_caches

    _, _, tail_kinds = layout(cfg)
    for i, (lp, kind) in enumerate(zip(params["tail"], tail_kinds)):
        h, c2 = step(lp, h, cache["tail"][i], kind)
        new_cache["tail"].append(c2)

    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params["embed"], h, cfg)[:, 0]
    return logits, new_cache
