"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Temporal mixing branch: linear → causal conv1d(4) → RG-LRU, gated by a
parallel GeLU branch, then an output projection.  Training/prefill uses a
log-depth ``associative_scan`` over the first-order linear recurrence
h_t = a_t ⊙ h_{t-1} + b_t; decode is the O(1) single-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, rng_for
from repro.sharding import annotate

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def init_rglru(rng, cfg: ModelConfig, name: str = "rg"):
    d, w = cfg.d_model, cfg.lru_width
    return {
        "wg": dense_init(rng_for(rng, name + "/wg"), (d, w)),
        "wx": dense_init(rng_for(rng, name + "/wx"), (d, w)),
        "conv_w": dense_init(rng_for(rng, name + "/convw"), (4, w), 0.2),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa_gate": dense_init(rng_for(rng, name + "/wa"), (w, w)),
        "ba_gate": jnp.zeros((w,), jnp.float32),
        "wi_gate": dense_init(rng_for(rng, name + "/wi"), (w, w)),
        "bi_gate": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 1.0, jnp.float32),  # Λ (learned, via softplus)
        "rg_out": dense_init(rng_for(rng, name + "/out"), (w, d)),
    }


def _conv_train(p, u, k: int = 4):
    w = p["conv_w"].astype(u.dtype)
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(k))
    return out + p["conv_b"].astype(u.dtype)


def _gates(p, u, cfg: ModelConfig):
    """RG-LRU gates from the post-conv input u (B, ..., W) in fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa_gate"] + p["ba_gate"])
    i = jax.nn.sigmoid(uf @ p["wi_gate"] + p["bi_gate"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])          # ≤ 0
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, gated_in


def rglru_train(p, x, cfg: ModelConfig):
    """x (B, S, d) → y (B, S, d)."""
    dt = cdtype(cfg)
    b, s, _ = x.shape
    gate = jax.nn.gelu(x @ p["wg"].astype(dt))
    u = _conv_train(p, x @ p["wx"].astype(dt))
    u = annotate(u, "batch", "seq", "lru")
    a, bterm = _gates(p, u, cfg)                         # (B,S,W) fp32

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    y = (gate.astype(jnp.float32) * h).astype(dt)
    return y @ p["rg_out"].astype(dt)


def init_cache_rglru(cfg: ModelConfig, batch: int, dtype=None):
    dt = dtype or cdtype(cfg)
    return {
        "conv": jnp.zeros((batch, 3, cfg.lru_width), dt),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_prefill(p, x, cfg: ModelConfig):
    """Returns (y, cache) — final recurrent state + conv tail."""
    dt = cdtype(cfg)
    gate = jax.nn.gelu(x @ p["wg"].astype(dt))
    ux = x @ p["wx"].astype(dt)
    u = _conv_train(p, ux)
    a, bterm = _gates(p, u, cfg)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    y = (gate.astype(jnp.float32) * h).astype(dt) @ p["rg_out"].astype(dt)
    s = x.shape[1]
    tail = ux[:, -3:] if s >= 3 else jnp.pad(ux, ((0, 0), (3 - s, 0), (0, 0)))
    return y, {"conv": tail.astype(dt), "h": h[:, -1]}


def rglru_decode(p, x, cfg: ModelConfig, cache):
    """x (B, 1, d) → (y, cache')."""
    dt = cdtype(cfg)
    b = x.shape[0]
    gate = jax.nn.gelu(x @ p["wg"].astype(dt))           # (B,1,W)
    ux = x @ p["wx"].astype(dt)                          # (B,1,W)
    buf = jnp.concatenate([cache["conv"], ux.astype(cache["conv"].dtype)],
                          axis=1)                        # (B,4,W)
    w = p["conv_w"].astype(dt)
    ut = (buf * w[None]).sum(axis=1) + p["conv_b"].astype(dt)  # (B,W)
    a, bterm = _gates(p, ut, cfg)                        # (B,W)
    h = a * cache["h"] + bterm
    y = (gate[:, 0].astype(jnp.float32) * h).astype(dt) @ p["rg_out"].astype(dt)
    return y[:, None, :], {"conv": buf[:, 1:], "h": h}


def rglru_sequential_ref(p, x, cfg: ModelConfig):
    """Step-by-step oracle for tests."""
    b, s, _ = x.shape
    cache = init_cache_rglru(cfg, b)

    def step(cache, xt):
        y, cache = rglru_decode(p, xt[:, None, :], cfg, cache)
        return cache, y[:, 0]

    _, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2)
