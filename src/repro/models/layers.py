"""Shared building blocks: inits, norms, embeddings, positions, MLPs.

Parameters are nested dicts of fp32 arrays; compute casts to the config
dtype at use.  All inits are traceable (dry-run builds parameter trees via
``jax.eval_shape`` — no allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rng_for(rng, name: str):
    """Deterministic per-parameter rng (stable under refactoring)."""
    h = 0
    for ch in name:
        h = (h * 131 + ord(ch)) % (2**31 - 1)
    return jax.random.fold_in(rng, h)


def dense_init(rng, shape, scale: float = 0.02):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * scale)


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(rng, cfg: ModelConfig, dim: int):
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32),
                "bias": jnp.zeros((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32)}


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """qk-norm over the head_dim axis: x (..., Dh), scale (Dh,)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / positions / head
# ---------------------------------------------------------------------------


def init_embed(rng, cfg: ModelConfig):
    p = {"table": dense_init(rng_for(rng, "embed"), (cfg.padded_vocab,
                                                     cfg.d_model), 1.0)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(rng_for(rng, "lm_head"),
                               (cfg.d_model, cfg.padded_vocab))
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    h = jnp.take(p["table"], tokens, axis=0).astype(cdtype(cfg))
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model**0.5, cdtype(cfg))
    return h


def lm_logits(p, h, cfg: ModelConfig):
    """h (..., d) -> logits (..., padded_vocab), fp32."""
    if cfg.tie_embeddings:
        w = p["table"].astype(cdtype(cfg)).T
    else:
        w = p["head"].astype(cdtype(cfg))
    logits = jnp.einsum("...d,dv->...v", h, w,
                        preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def sinusoidal_pos(seq_len: int, dim: int, offset=0):
    pos = jnp.arange(seq_len)[:, None] + offset
    i = jnp.arange(dim // 2)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * i / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh) with positions (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    sin = jnp.sin(ang)[..., None, :]                    # (..., S, 1, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: int, name: str = "mlp"):
    d = cfg.d_model
    return {
        "w_gate": dense_init(rng_for(rng, name + "/gate"), (d, d_ff)),
        "w_up": dense_init(rng_for(rng, name + "/up"), (d, d_ff)),
        "w_down": dense_init(rng_for(rng, name + "/down"), (d_ff, d)),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    dt = cdtype(cfg)
    act = jax.nn.gelu if cfg.mlp_kind == "geglu" else jax.nn.silu
    g = act(x @ p["w_gate"].astype(dt))
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Frontend stubs (vision / audio): precomputed embeddings -> d_model
# ---------------------------------------------------------------------------


def init_frontend(rng, cfg: ModelConfig):
    if cfg.frontend is None:
        return None
    return {"proj": dense_init(rng_for(rng, "frontend/proj"),
                               (cfg.d_model, cfg.d_model))}


def apply_frontend(p, embeds, cfg: ModelConfig):
    return embeds.astype(cdtype(cfg)) @ p["proj"].astype(cdtype(cfg))
