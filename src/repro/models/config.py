"""Model configuration system for the assigned architecture pool.

One frozen dataclass tree describes every family: dense / GQA transformers
(with sliding-window, soft-capping, qk-norm variants), MLA (DeepSeek-V3),
MoE (shared + routed top-k), Mamba2 SSD, RG-LRU hybrids (RecurrentGemma),
encoder–decoder (Seamless backbone), and modality-stub frontends (ViT/audio
embeddings supplied by ``input_specs``).

``layer_pattern`` is a repeating string over sub-layer kinds:
  G = global attention, L = local (sliding-window) attention,
  R = RG-LRU recurrent block, M = Mamba2 SSD block.
``n_layers`` need not be a multiple of ``len(layer_pattern)``; the trailing
remainder is instantiated unrolled.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 8
    n_shared: int = 0
    top_k: int = 2
    d_expert: int = 1408          # routed expert hidden width
    d_shared: int = 0             # shared expert hidden width (0 = d_expert)
    router: str = "softmax"       # "softmax" | "sigmoid" (deepseek-v3)
    norm_topk: bool = True
    aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25
    n_dense_layers: int = 0       # leading dense layers (deepseek: 3)
    d_ff_dense: int = 0           # width of those dense layers
    impl: str = "sharded"         # dispatch: "sharded" (per-data-shard
                                  # capacity buffers, EP-friendly) |
                                  # "global" (naive global buffer baseline)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    layer_pattern: str = "G"
    mlp_kind: str = "swiglu"      # swiglu | geglu
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_norms: bool = False      # gemma2/3 sandwich norms
    tie_embeddings: bool = True
    embed_scale: bool = False     # multiply embeddings by sqrt(d_model)
    pos_kind: str = "rope"        # rope | abs_sinusoidal
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None  # gemma3: local layers use 10k
    sliding_window: Optional[int] = None      # for 'L' layers
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    query_scale: Optional[float] = None       # default head_dim**-0.5
    attn_kind: str = "gqa"        # gqa | mla
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp_depth: int = 0            # deepseek multi-token-prediction heads
    # encoder–decoder
    enc_layers: int = 0           # >0 => enc-dec; n_layers is decoder depth
    # modality frontend stubs
    frontend: Optional[str] = None            # "vision_stub" | "audio_stub"
    n_frontend_tokens: int = 0                # prepended embedding tokens
    # recurrent (RG-LRU) width
    lru_width: int = 0
    # vocab padding for clean sharding
    pad_vocab_multiple: int = 128
    # training numerics
    dtype: str = "bfloat16"

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return set(self.layer_pattern) <= {"M"}

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (SSM / hybrid /
        local-attention layers bound the per-layer KV to the window; global
        layers handled by sequence-parallel decode)."""
        return ("M" in self.layer_pattern or "R" in self.layer_pattern
                or "L" in self.layer_pattern)

    def pattern_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def serving_gate_report(self) -> Optional[str]:
        """Why this config cannot serve chunked/paged — or None if it can.

        The continuous engine covers both layer-state families (see
        :mod:`repro.core.layer_state`): ring-KV layers with a retention
        rule — 'G' layers retire behind the clustered coverage frontier
        (FrontierRetention) or a block quota (QuotaRetention), 'L'
        layers behind their own sliding window (WindowRetention) — and
        recurrent-state layers ('M' Mamba2 SSD, 'R' RG-LRU) whose
        fixed-size state is advanced in the mixed launch and never
        retires (RecurrentRetention).  What remains ungated: MLA latent
        caches, encoder–decoder cross attention, modality frontends,
        'L' without a window, and unknown kinds.

        The report enumerates **every** unsupported (layer, kind) pair
        — not just the first blocking layer — so a mixed config's
        diagnostics name all the gaps at once and the validation error
        says *what* to fix, not just 'unsupported'.
        """
        problems = []
        if self.is_encdec:
            problems.append("encoder-decoder cross-attention "
                            f"(enc_layers={self.enc_layers}) has no "
                            "retention policy")
        if self.attn_kind == "mla":
            problems.append("attn_kind 'mla' caches latent KV, which no "
                            "retention policy covers")
        if self.n_frontend_tokens:
            problems.append(f"modality frontend ({self.n_frontend_tokens} "
                            "prepended tokens) breaks position-0 admission")
        kind_names = {"G": "global attention", "L": "local attention",
                      "R": "RG-LRU recurrence", "M": "Mamba2 SSD"}
        for i in range(self.n_layers):
            kind = self.pattern_for_layer(i)
            if kind in ("G", "M", "R"):
                continue
            if kind == "L" and self.sliding_window:
                continue
            what = kind_names.get(kind, f"unknown kind '{kind}'")
            why = (" without sliding_window" if kind == "L"
                   else " has no layer-state family")
            problems.append(f"layer {i}: {what}{why}")
        if not problems:
            return None
        return (f"model '{self.name}' needs state handling the engine "
                "lacks: " + "; ".join(problems) +
                " — global attention ('G'), sliding-window local layers "
                "('L'), and recurrent-state layers ('M' Mamba2 SSD, 'R' "
                "RG-LRU) serve chunked/paged")

    def validate(self) -> "ModelConfig":
        assert self.n_heads % self.n_kv_heads == 0 or self.attn_kind == "mla"
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.n_routed
        if "M" in self.layer_pattern:
            assert self.ssm is not None
        if "R" in self.layer_pattern:
            assert self.lru_width > 0
        if self.attn_kind == "mla":
            assert self.mla is not None
        return self


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (architecture × input-shape) cell."""
    shape_name: str               # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
