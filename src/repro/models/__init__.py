from repro.models import config, transformer  # noqa: F401
