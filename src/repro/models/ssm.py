"""Mamba-2 (SSD — state-space duality) block.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic
attention-like term + inter-chunk linear state recurrence), so cost is
O(S·Q) with chunk Q and the state never materializes per position.
Decode is the O(1)-per-token recurrence on an (H, hd, N) state with a
rolling depthwise-conv buffer.  Validated against a sequential-scan oracle
in tests/test_models_parity.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, dense_init, rng_for
from repro.sharding import annotate


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, h, conv_ch


def init_ssm(rng, cfg: ModelConfig, name: str = "ssm"):
    s = cfg.ssm
    d = cfg.d_model
    d_in, h, conv_ch = _dims(cfg)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + h
    return {
        "in_proj": dense_init(rng_for(rng, name + "/in"), (d, proj_out)),
        "conv_w": dense_init(rng_for(rng, name + "/convw"),
                             (s.d_conv, conv_ch), 0.2),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(rng_for(rng, name + "/out"), (d_in, d)),
    }


def _split(p, x, cfg: ModelConfig):
    s = cfg.ssm
    d_in, h, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    zxbcdt = x @ p["in_proj"].astype(cdtype(cfg))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * gn]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * gn:]
    return z, xbc, dt_raw


def _conv_train(p, xbc, cfg: ModelConfig):
    """Causal depthwise conv over time: xbc (B, S, C)."""
    k = cfg.ssm.d_conv
    w = p["conv_w"].astype(xbc.dtype)                    # (k, C)
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _segsum(a):
    """Within-chunk cumulative-decay matrix: a (..., Q) →
    L (..., Q, Q) with L[i, j] = sum(a[j+1..i]) for i >= j, -inf otherwise."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # (..., i, j)
    iq = jnp.arange(q)
    mask = iq[:, None] >= iq[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD forward.

    x (B, S, H, P), dt (B, S, H) (post-softplus), A (H,) (negative),
    B, C (B, S, G, N), D (H,) → y (B, S, H, P) and final state
    (B, H, P, N).  Heads are grouped: G divides H.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    a = dtc * A                                          # (B,Nc,Q,H) ≤ 0
    cum = jnp.cumsum(a, axis=2)                          # within-chunk

    # --- intra-chunk (diagonal blocks) ---
    L = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))        # (B,Nc,H,Q,Q)
    scores = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)    # (B,Nc,G,Q,S)
    scores = jnp.repeat(scores, rep, axis=2)             # (B,Nc,H,Q,S)
    y_diag = jnp.einsum("bchqs,bcsh,bcshp->bcqhp",
                        scores * L, dtc, xc)

    # --- chunk states ---
    Bh = jnp.repeat(Bc, rep, axis=3)                     # (B,Nc,Q,H,N)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,Nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bh, decay_out * dtc, xc)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,Nc,H)

    def scan_fn(carry, xs):
        st, = (carry,)
        dec, snew = xs                                   # (B,H), (B,H,P,N)
        out = st
        st = st * dec[:, :, None, None] + snew
        return st, out

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prefix = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prefix = prefix.transpose(1, 0, 2, 3, 4)             # state BEFORE chunk

    decay_in = jnp.exp(cum)                              # (B,Nc,Q,H)
    Ch = jnp.repeat(Cc, rep, axis=3)                     # (B,Nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prefix, decay_in)

    y = (y_diag + y_off).reshape(b, s + pad, h, p)[:, :s]
    y = y + (D[None, None, :, None] * x[:, :s].astype(jnp.float32))
    return y, final


def ssm_train(p, x, cfg: ModelConfig):
    """x (B, S, d) → y (B, S, d)."""
    s_cfg = cfg.ssm
    dt_ = cdtype(cfg)
    d_in, h, _ = _dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state
    z, xbc, dt_raw = _split(p, x, cfg)
    xbc = _conv_train(p, xbc, cfg)
    xh = xbc[..., :d_in]
    Bm = xbc[..., d_in:d_in + gn]
    Cm = xbc[..., d_in + gn:]
    b, s, _ = x.shape
    xh = annotate(xh.reshape(b, s, h, s_cfg.head_dim),
                  "batch", "seq", "ssm_heads", "head_dim")
    Bm = Bm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    Cm = Cm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], s_cfg.chunk)
    y = y.reshape(b, s, d_in).astype(dt_)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    gated = y * jax.nn.silu(z)
    var = (gated.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["norm"]).astype(dt_)
    return gated @ p["out_proj"].astype(dt_)


def init_cache_ssm(cfg: ModelConfig, batch: int, dtype=None):
    s = cfg.ssm
    dt = dtype or cdtype(cfg)
    d_in, h, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dt),
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(p, x, cfg: ModelConfig, cache):
    """x (B, 1, d) → (y (B, 1, d), cache')."""
    s_cfg = cfg.ssm
    dt_ = cdtype(cfg)
    d_in, h, conv_ch = _dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state
    b = x.shape[0]
    z, xbc, dt_raw = _split(p, x, cfg)                   # (B,1,·)

    conv_buf = jnp.concatenate([cache["conv"],
                                xbc.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(dt_)                          # (k, C)
    xbc_t = jax.nn.silu((conv_buf * w[None]).sum(axis=1)
                        + p["conv_b"].astype(dt_))       # (B, C)
    new_conv = conv_buf[:, 1:]

    xh = xbc_t[:, :d_in].reshape(b, h, s_cfg.head_dim).astype(jnp.float32)
    Bm = xbc_t[:, d_in:d_in + gn].reshape(b, s_cfg.n_groups, s_cfg.d_state)
    Cm = xbc_t[:, d_in + gn:].reshape(b, s_cfg.n_groups, s_cfg.d_state)
    rep = h // s_cfg.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                             # (H,)
    decay = jnp.exp(dt * A)                              # (B,H)
    st = cache["ssm"]
    st = (st * decay[:, :, None, None]
          + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh))
    y = jnp.einsum("bhpn,bhn->bhp", st, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(dt_)
    gated = y * jax.nn.silu(z)
    var = (gated.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["norm"]).astype(dt_)
    out = gated @ p["out_proj"].astype(dt_)
    return out, {"conv": new_conv, "ssm": st}


def ssm_sequential_ref(p, x, cfg: ModelConfig):
    """Sequential-recurrence oracle (tests only): step ssm_decode over S."""
    b, s, _ = x.shape
    cache = init_cache_ssm(cfg, b)

    def step(cache, xt):
        y, cache = ssm_decode(p, xt[:, None, :], cfg, cache)
        return cache, y[:, 0]

    _, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2)
