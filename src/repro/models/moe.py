"""Mixture-of-Experts layer: shared + routed top-k, capacity-based dispatch.

Dispatch is the sort-rank/capacity-buffer scheme (GShard-style, static
shapes, token-dropping above capacity): tokens are ranked within their
chosen expert, scattered into an (E, C, d) buffer, run through a batched
expert matmul (EP: the E axis shards over the model mesh axis when
divisible — DeepSeek's 256; otherwise the expert FFN width shards — Qwen2
MoE's 60), and combined back with router weights.

Routers: softmax (Qwen2-MoE, no top-k renorm) and sigmoid with selection
bias (DeepSeek-V3 aux-loss-free balancing; the bias is a non-gradient
buffer updated from expert load by the trainer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, cdtype, dense_init, init_mlp, rng_for
from repro.sharding import annotate, annotate_prio


def init_moe(rng, cfg: ModelConfig, name: str = "moe"):
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": dense_init(rng_for(rng, name + "/router"), (d, m.n_routed)),
        "bias": jnp.zeros((m.n_routed,), jnp.float32),
        "w_gate": dense_init(rng_for(rng, name + "/wg"),
                             (m.n_routed, d, m.d_expert)),
        "w_up": dense_init(rng_for(rng, name + "/wu"),
                           (m.n_routed, d, m.d_expert)),
        "w_down": dense_init(rng_for(rng, name + "/wd"),
                             (m.n_routed, m.d_expert, d)),
    }
    if m.n_shared > 0:
        width = m.d_shared or m.d_expert * m.n_shared
        p["shared"] = init_mlp(rng, cfg, width, name + "/shared")
    return p


def route(p, x_flat, cfg: ModelConfig):
    """x_flat (T, d) → (weights (T, K), idx (T, K), probs (T, E))."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if m.router == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        sel = probs + p["bias"][None, :]
        _, idx = jax.lax.top_k(sel, m.top_k)
        w = jnp.take_along_axis(probs, idx, axis=1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-20)
    return w.astype(jnp.float32), idx.astype(jnp.int32), probs


def update_router_bias(bias, expert_load, gamma: float = 1e-3):
    """DeepSeek-V3 aux-loss-free balancing: nudge the (non-gradient)
    selection bias toward under-loaded experts.  Called by the trainer
    from the step metrics: bias += γ·sign(mean_load − load)."""
    load = expert_load.astype(jnp.float32)
    return bias + gamma * jnp.sign(load.mean() - load)


def capacity(cfg: ModelConfig, t: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * t * m.top_k / m.n_routed)
    return max(8, ((c + 7) // 8) * 8)


def _n_data_shards() -> int:
    """Data-parallel shard count from the active rules context (1 when no
    mesh is active, e.g. CPU unit tests)."""
    from repro.sharding import current_rules
    r = current_rules()
    if r is None:
        return 1
    n = 1
    for ax in (r.table.get("batch") or ()):
        n *= r.mesh.shape[ax]
    return n


def _a2a_geometry(cfg: ModelConfig, t: int):
    """Returns (ep_axes, n_ep, batch_axes, n_batch) when the explicit
    all-to-all dispatch applies: one routed expert per EP-group device and
    token count divisible across (batch × model) chunks."""
    from repro.sharding import current_rules
    r = current_rules()
    if r is None:
        return None
    ep_axes = ("model", "data")
    n_ep = 1
    for ax in ep_axes:
        n_ep *= r.mesh.shape[ax]
    batch_axes = tuple(r.table.get("batch") or ())
    n_batch = 1
    for ax in batch_axes:
        n_batch *= r.mesh.shape[ax]
    if cfg.moe.n_routed != n_ep:
        return None
    t_loc = t // max(n_batch, 1)
    if t % max(n_batch, 1) != 0 or t_loc % r.mesh.shape["model"] != 0:
        return None
    return r, ep_axes, n_ep, batch_axes


def apply_moe(p, x, cfg: ModelConfig):
    """x (B, S, d) → (y (B, S, d), metrics dict with aux loss & load).

    impl="a2a": explicit shard_map dispatch — each device owns ONE routed
    expert (EP over model×data); tokens are packed into per-destination
    send buffers and exchanged with ``lax.all_to_all``, processed by the
    owner, and returned by the inverse all-to-all.  Wire volume is
    Θ(tokens·top_k·d) per round trip — the physical minimum — instead of
    the buffer all-gathers GSPMD synthesizes.  Requires n_routed ==
    model×data (DeepSeek's 256 on the 16×16 pod); otherwise falls back to:

    impl="sharded" (default): per-data-shard capacity buffers (DS, E,
    C_loc, d) under pure GSPMD.  The token→buffer scatter is local to the
    data shard, so cross-device traffic reduces to the expert-dim
    resharding of the buffers instead of the all-reduce of a fully-
    replicated global buffer that the naive formulation (impl="global")
    provokes.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    if m.impl == "a2a":
        geo = _a2a_geometry(cfg, t)
        if geo is not None:
            return _apply_moe_a2a(p, x, cfg, geo)
    ds = _n_data_shards() if m.impl in ("sharded", "a2a") else 1
    if t % ds != 0:
        ds = 1
    y, counts, probs, keep_mean = _dispatch_compute(p, x.reshape(t, d), cfg,
                                                    ds)
    y = y.reshape(b, s, d)
    if m.n_shared > 0:
        y = y + apply_mlp(p["shared"], x, cfg)

    # load-balance metrics: f_e = dispatch fraction, P_e = mean router prob
    f = counts.astype(jnp.float32) / jnp.maximum(t * m.top_k, 1)
    pbar = probs
    aux = (m.n_routed * jnp.sum(f * pbar)) * m.aux_loss_coef
    return y, {"aux_loss": aux, "expert_load": counts,
               "drop_frac": 1.0 - keep_mean}


def _apply_moe_a2a(p, x, cfg: ModelConfig, geo):
    """Explicit EP all-to-all dispatch under shard_map (see apply_moe)."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    dt = cdtype(cfg)
    rules, ep_axes, n_ep, batch_axes = geo
    mesh = rules.mesh
    b, s, d = x.shape
    model_n = mesh.shape["model"]
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
    all_axes = tuple(mesh.axis_names)

    def block(xb, router, bias, wg, wu, wd):
        # xb (B_loc, S, d); wg/wu/wd (1, d, f)/(1, f, d) — my expert
        bl = xb.shape[0]
        t_loc = bl * s
        tc = t_loc // model_n                            # my chunk size
        j = jax.lax.axis_index("model")
        xf = xb.reshape(t_loc, d)
        chunk = jax.lax.dynamic_slice(xf, (j * tc, 0), (tc, d))

        # route my chunk
        logits = jnp.einsum("td,de->te", chunk.astype(jnp.float32),
                            router.astype(jnp.float32))
        if m.router == "sigmoid":
            probs = jax.nn.sigmoid(logits)
            _, idx = jax.lax.top_k(probs + bias[None, :], m.top_k)
            w = jnp.take_along_axis(probs, idx, axis=1)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
            w, idx = jax.lax.top_k(probs, m.top_k)
        if m.norm_topk:
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-20)

        k = m.top_k
        tk = tc * k
        flat_e = idx.reshape(tk)                         # dst device per slot
        cap = max(8, int(-(-m.capacity_factor * tk // n_ep)))
        sort_idx = jnp.argsort(flat_e)
        ranks = jnp.zeros((tk,), jnp.int32).at[sort_idx].set(
            jnp.arange(tk, dtype=jnp.int32))
        counts = jnp.bincount(flat_e, length=n_ep)
        starts = jnp.cumsum(counts) - counts
        pos = ranks - starts[flat_e]
        keep = (pos < cap).astype(jnp.float32)
        pos_c = jnp.clip(pos, 0, cap - 1)
        token_id = jnp.arange(tk, dtype=jnp.int32) // k

        send = jnp.zeros((n_ep, cap, d), dt).at[flat_e, pos_c].add(
            jnp.take(chunk, token_id, axis=0)
            * keep[:, None].astype(dt))
        # exchange: slot [i] of recv = buffer destined to me from device i
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)

        # my (single) expert over everything I received
        rows = recv.reshape(n_ep * cap, d)
        g = act(rows @ wg[0])
        u = rows @ wu[0]
        out_rows = (g * u) @ wd[0]
        ret = jax.lax.all_to_all(out_rows.reshape(n_ep, cap, d), ep_axes,
                                 split_axis=0, concat_axis=0, tiled=True)

        y_slots = ret[flat_e, pos_c]                     # (TK, d)
        wk = (w.reshape(tk) * keep).astype(dt)
        y_chunk = jnp.zeros((tc, d), dt).at[token_id].add(
            y_slots * wk[:, None])
        y_full = jax.lax.all_gather(y_chunk, "model", axis=0,
                                    tiled=True)          # (T_loc, d)

        # metrics (replicated): global expert load + mean probs + keep
        load = jax.lax.psum(counts.astype(jnp.float32), all_axes)
        psum_probs = jax.lax.psum(probs.sum(0), all_axes)
        n_tok = jax.lax.psum(jnp.float32(tc), all_axes)
        keep_mean = jax.lax.psum(keep.sum(), all_axes) / jnp.maximum(
            jax.lax.psum(jnp.float32(tk), all_axes), 1.0)
        return (y_full.reshape(bl, s, d), load, psum_probs / n_tok,
                keep_mean)

    bspec = P(batch_axes if batch_axes else None, None, None)
    espec = P(ep_axes, None, None)
    y, load, pbar, keep_mean = jax.shard_map(
        block, mesh=mesh,
        in_specs=(bspec, P(None, None), P(None), espec, espec, espec),
        out_specs=(bspec, P(), P(), P()),
        check_vma=False,
    )(x, p["router"].astype(jnp.float32), p["bias"].astype(jnp.float32),
      p["w_gate"].astype(dt), p["w_up"].astype(dt), p["w_down"].astype(dt))

    if m.n_shared > 0:
        y = y + apply_mlp(p["shared"], x, cfg)
    t = b * s
    f = load / jnp.maximum(t * m.top_k, 1)
    aux = (m.n_routed * jnp.sum(f * pbar)) * m.aux_loss_coef
    return y, {"aux_loss": aux, "expert_load": load,
               "drop_frac": 1.0 - keep_mean}


def _dispatch_compute(p, x_flat, cfg: ModelConfig, ds: int):
    """Per-data-shard capacity-buffer dispatch (ds=1 == global baseline).

    Returns (y (T, d), counts (E,), mean_probs (E,), keep_mean scalar).
    """
    m = cfg.moe
    dt = cdtype(cfg)
    t, d = x_flat.shape
    e, k = m.n_routed, m.top_k
    tl = t // ds                                         # tokens per shard

    w, idx, probs = route(p, x_flat, cfg)                # (T,K),(T,K),(T,E)

    cap = capacity(cfg, tl)
    xs = x_flat.reshape(ds, tl, d)
    xs = annotate(xs, "batch", None, "d_model")
    flat_e = idx.reshape(ds, tl * k)                     # (DS, TK)
    w_flat = w.reshape(ds, tl * k)
    tk = tl * k
    row = jnp.arange(ds, dtype=jnp.int32)[:, None]       # (DS, 1)
    token_id = (jnp.arange(tk, dtype=jnp.int32) // k)[None, :]  # (1, TK)

    sort_idx = jnp.argsort(flat_e, axis=1)               # stable per shard
    ranks = jnp.zeros((ds, tk), jnp.int32).at[
        jnp.broadcast_to(row, (ds, tk)), sort_idx].set(
        jnp.broadcast_to(jnp.arange(tk, dtype=jnp.int32)[None], (ds, tk)))
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)  # (DS, TK, E)
    counts_s = onehot.sum(axis=1)                        # (DS, E)
    starts = jnp.cumsum(counts_s, axis=1) - counts_s     # (DS, E)
    pos_in_e = ranks - jnp.take_along_axis(
        starts, flat_e, axis=1).astype(jnp.int32)
    keep = (pos_in_e < cap).astype(jnp.float32)          # (DS, TK)
    pos_c = jnp.clip(pos_in_e, 0, cap - 1)

    gathered = jnp.take_along_axis(
        xs, jnp.broadcast_to(token_id, (ds, tk))[..., None], axis=1)
    gathered = gathered * keep[..., None].astype(dt)     # (DS, TK, d)
    buf = jnp.zeros((ds, e, cap, d), dt).at[
        jnp.broadcast_to(row, (ds, tk)), flat_e, pos_c].add(gathered)
    buf = annotate_prio(buf, ("batch", "experts", None, "d_model"),
                        priority=(1,))

    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    h = annotate_prio(g * u, ("batch", "experts", None, "expert_ff"),
                      priority=(1,))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    out_buf = annotate_prio(out_buf, ("batch", "experts", None, "d_model"),
                            priority=(1,))

    y_slots = out_buf[jnp.broadcast_to(row, (ds, tk)), flat_e, pos_c]
    wk = (w_flat * keep).astype(dt)
    y = jnp.zeros((ds, tl, d), dt).at[
        jnp.broadcast_to(row, (ds, tk)),
        jnp.broadcast_to(token_id, (ds, tk))].add(y_slots * wk[..., None])
    y = annotate(y, "batch", None, "d_model")

    counts = counts_s.sum(axis=0)
    return y.reshape(t, d), counts, probs.mean(0), keep.mean()
