"""Attention: chunked (flash-style) softmax, GQA variants, MLA, caches.

Key properties:
  * ``chunked_attention`` scans KV in fixed chunks with an online softmax —
    no (Sq, Skv) tensor is ever materialized, which is what lets the 32k
    prefill cells compile inside HBM.
  * sliding-window ('L') layers keep ring-buffer KV caches of size
    ``window`` — decode_32k/long_500k cells only pay window-sized memory
    for local layers.
  * RoPE is applied at absolute positions before caching, so ring-buffer
    entries stay valid.
  * MLA (DeepSeek-V3) caches only the compressed latent (c_kv, k_pe) and
    decodes in the absorbed form (query hits the latent directly).
  * decode attention is a plain masked softmax over the cache: under pjit,
    GSPMD partitions the cache sequence axis (sequence-parallel decode for
    long_500k) and inserts the flash-decoding style partial reductions.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, cdtype, dense_init, rms_head_norm,
                                 rng_for)
from repro.sharding import annotate

NEG = -1e30


def _softcap(s, cap: Optional[float]):
    if cap is None:
        return s
    return jnp.tanh(s / cap) * cap


def chunked_attention(q, k, v, *, causal: bool, scale: float,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_offset: int = 0, chunk_kv: int = 1024):
    """Online-softmax attention.

    q (B, Sq, Hq, Dh), k (B, Skv, Hkv, Dh), v (B, Skv, Hkv, Dv)
    → (B, Sq, Hq, Dv).  Hq must be a multiple of Hkv (GQA grouping).
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    chunk_kv = min(chunk_kv, skv)

    qh = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh)
    qh = qh.transpose(0, 2, 3, 1, 4)                     # (B, Hkv, G, Sq, Dh)

    pad = (-skv) % chunk_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (skv + pad) // chunk_kv
    kc = k.reshape(b, nc, chunk_kv, hkv, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, chunk_kv, hkv, dv).transpose(1, 0, 3, 2, 4)

    qpos = q_offset + jnp.arange(sq)                     # (Sq,)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs                                  # (B,Hkv,C,Dh/Dv)
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qh, kb.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        kpos = ci * chunk_kv + jnp.arange(chunk_kv)      # (C,)
        ok = (kpos < skv)[None, :]
        if causal:
            ok = ok & (qpos[:, None] >= kpos[None, :])
        if window is not None:
            ok = ok & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(ok[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


def ring_slot_positions(cache_size: int, t):
    """Absolute position stored in each ring slot at time t (next write = t).

    For t <= cache_size slot s holds position s (s < t valid); afterwards the
    live window is [t - W, t) with slot(p) = p % W.  ``t`` may be a scalar
    (→ (W,)) or a per-slot (B,) vector (→ (B, W)) for continuous batching.
    Delegates to the canonical ring math in core.kv_compress.
    """
    from repro.core.kv_compress import ring_positions
    return ring_positions(cache_size, t)


def decode_attention(q, k_cache, v_cache, *, t, scale: float,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     ring: bool = False, chunk_len=None):
    """One-token (or chunked mixed-mode) attention over a cache.

    Decode form — q (B, Hq, Dh), k_cache (B, Sc, Hkv, Dh), v_cache
    (B, Sc, Hkv, Dv).  ``t`` = current absolute position (the query's
    position; cache entries with position < t participate); scalar or
    per-slot (B,) for continuous batching.  Under pjit the Sc axis may be
    sharded (sequence-parallel long-context decode).

    Mixed chunk form (decode-interleaved prefill) — q (B, L, Hq, Dh) with
    per-slot ``chunk_len`` (B,) valid rows and ``t`` = cache length
    *before* the chunk rows were written: row i queries absolute position
    t + i and sees cache entries with position < t + i + 1 (the chunk's
    own rows are already in the cache, so intra-chunk causality falls out
    of the same mask).  Rows at index >= chunk_len are garbage and must
    be discarded by the caller.  Returns (B, L, Hq, Dv).
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, l, hq, dh = q.shape
    _, sc, hkv, _ = k_cache.shape
    g = hq // hkv
    qh = q.astype(jnp.float32).reshape(b, l, hkv, g, dh)

    s = jnp.einsum("blhgd,bshd->bhlgs", qh,
                   k_cache.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    tb = jnp.broadcast_to(jnp.asarray(t), (b,))
    if squeeze:
        qpos1 = tb[:, None]                              # (B, 1) = qpos + 1
        tw = tb                                          # writes included
    else:
        cl = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
        qpos1 = tb[:, None] + jnp.arange(l)[None, :] + 1
        tw = tb + cl                                     # ring holds t + cl
    pos = ring_slot_positions(sc, tw) if ring else jnp.arange(sc)
    pos = jnp.broadcast_to(pos, (b, sc))
    ok = ((pos >= 0)[:, None, :]
          & (pos[:, None, :] < qpos1[:, :, None]))       # (B, L, Sc)
    if window is not None:
        # query position is qpos1-1; training mask is qpos - kpos < window,
        # i.e. kpos >= qpos1 - window
        ok = ok & (pos[:, None, :] >= qpos1[:, :, None] - window)
    s = jnp.where(ok[:, None, :, None, :], s, NEG)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    lsum = p.sum(-1, keepdims=True)
    out = jnp.einsum("bhlgs,bshd->blhgd", p / jnp.maximum(lsum, 1e-30),
                     v_cache.astype(jnp.float32))
    out = out.reshape(b, l, hq, -1).astype(q.dtype)
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# GQA attention layer (self-attention)
# ---------------------------------------------------------------------------


def init_attn(rng, cfg: ModelConfig, name: str = "attn"):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(rng_for(rng, name + "/wq"), (d, hq * dh)),
        "wk": dense_init(rng_for(rng, name + "/wk"), (d, hkv * dh)),
        "wv": dense_init(rng_for(rng, name + "/wv"), (d, hkv * dh)),
        "wo": dense_init(rng_for(rng, name + "/wo"), (hq * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _theta(cfg: ModelConfig, layer_kind: str) -> float:
    if layer_kind == "L" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _qkv(p, x, cfg: ModelConfig, positions, layer_kind: str, kv_repeat: int,
         rope: bool = True):
    dt = cdtype(cfg)
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dt)).reshape(b, s, hq, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, hkv, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if rope and cfg.pos_kind == "rope":
        th = _theta(cfg, layer_kind)
        q = apply_rope(q, positions, th)
        k = apply_rope(k, positions, th)
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    return q, k, v


def _scale(cfg: ModelConfig) -> float:
    return (cfg.query_scale if cfg.query_scale is not None
            else cfg.head_dim**-0.5)


def attn_train(p, x, cfg: ModelConfig, *, layer_kind: str, positions,
               kv_repeat: int = 1, causal: bool = True, chunk_kv: int = 1024):
    q, k, v = _qkv(p, x, cfg, positions, layer_kind, kv_repeat)
    window = cfg.sliding_window if layer_kind == "L" else None
    out = chunked_attention(q, k, v, causal=causal, scale=_scale(cfg),
                            window=window, softcap=cfg.attn_logit_softcap,
                            chunk_kv=chunk_kv)
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ p["wo"].astype(cdtype(cfg))


def init_cache_attn(cfg: ModelConfig, layer_kind: str, batch: int,
                    max_seq: int, kv_repeat: int = 1, dtype=None,
                    quantized: bool = False):
    dt = dtype or cdtype(cfg)
    window = cfg.sliding_window if layer_kind == "L" else None
    sc = min(max_seq, window) if window else max_seq
    hkv = cfg.n_kv_heads * kv_repeat
    shape = (batch, sc, hkv, cfg.head_dim)
    if quantized:
        # int8 KV with a per-head static scale (set at prefill): halves
        # HBM footprint + stream bytes of decode at <0.5% score error
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.ones((hkv,), jnp.float32),
                "v_scale": jnp.ones((hkv,), jnp.float32)}
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _cache_read(cache, cfg):
    """Dequantize-on-read for int8 caches; identity otherwise."""
    if "k_scale" not in cache:
        return cache["k"], cache["v"]
    dt = cdtype(cfg)
    k = cache["k"].astype(dt) * cache["k_scale"][None, None, :, None].astype(dt)
    v = cache["v"].astype(dt) * cache["v_scale"][None, None, :, None].astype(dt)
    return k, v


def _cache_write(cache, k_new, v_new, slot):
    """Quantize-on-write for int8 caches (static per-head scale).

    k/v_new (B, L, Hkv, Dh); ``slot`` (B, L) per-row write position (a
    scatter, so continuous-batching slots at different depths coexist and
    a prompt chunk lands in one call).  Out-of-range slots (masked chunk
    rows pass Sc) are dropped."""
    if "k_scale" in cache:
        ks = cache["k_scale"][None, None, :, None]
        vs = cache["v_scale"][None, None, :, None]
        k_new = jnp.clip(jnp.round(k_new.astype(jnp.float32) / ks),
                         -127, 127).astype(jnp.int8)
        v_new = jnp.clip(jnp.round(v_new.astype(jnp.float32) / vs),
                         -127, 127).astype(jnp.int8)
    b = k_new.shape[0]
    rows = jnp.arange(b)[:, None]
    kc = cache["k"].at[rows, slot].set(k_new.astype(cache["k"].dtype),
                                       mode="drop")
    vc = cache["v"].at[rows, slot].set(v_new.astype(cache["v"].dtype),
                                       mode="drop")
    return kc, vc


def attn_prefill(p, x, cfg: ModelConfig, *, layer_kind: str, positions,
                 kv_repeat: int = 1, chunk_kv: int = 1024):
    """Causal prefill returning (y, cache).  'L' layers keep only the last
    ``window`` keys, placed at their ring slots."""
    q, k, v = _qkv(p, x, cfg, positions, layer_kind, kv_repeat)
    window = cfg.sliding_window if layer_kind == "L" else None
    out = chunked_attention(q, k, v, causal=True, scale=_scale(cfg),
                            window=window, softcap=cfg.attn_logit_softcap,
                            chunk_kv=chunk_kv)
    b, s, _, _ = out.shape
    y = out.reshape(b, s, -1) @ p["wo"].astype(cdtype(cfg))

    if window is not None and s > window:
        tail_k, tail_v = k[:, -window:], v[:, -window:]
        # slot for absolute position pos is pos % window; tail position j
        # (0-based in the tail) is absolute s - window + j
        slots = jnp.mod(s - window + jnp.arange(window), window)
        inv = jnp.argsort(slots)
        cache = {"k": tail_k[:, inv], "v": tail_v[:, inv]}
    else:
        sc = window if window else s
        padn = sc - s if window else 0
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, padn), (0, 0), (0, 0))) if padn else k,
            "v": jnp.pad(v, ((0, 0), (0, padn), (0, 0), (0, 0))) if padn else v,
        }
    return y, cache


def init_cache_attn_clustered(cfg: ModelConfig, batch: int, *,
                              n_clusters: int = 512, tail: int = 256,
                              kv_repeat: int = 1, dtype=None,
                              pool_blocks: int = 0, block_size: int = 0):
    """Clustered KV cache for global-attention layers (the paper's memory
    manager): C median centroids (+ per-centroid counts) stand in for the
    compressed prefix; the most recent ``tail`` keys stay exact in a ring.
    The serving runtime refreshes centroids with core.kv_compress every
    ``tail`` steps, so the prefix is always covered.

    With ``pool_blocks``/``block_size`` set (paged serving), the tail
    leaves become a shared block pool ``(pool_blocks, block_size, H, Dh)``
    instead of a per-slot ring — ring offset ``r`` of a slot lives at
    offset ``r % block_size`` of the physical block its block table maps
    for ring block ``r // block_size`` (runtime/kv_pool.py).  Centroids,
    counts, and ``cov`` stay dense per slot either way."""
    dt = dtype or cdtype(cfg)
    hkv = cfg.n_kv_heads * kv_repeat
    dh = cfg.head_dim
    if pool_blocks:
        tail_shape = (pool_blocks, block_size, hkv, dh)
    else:
        tail_shape = (batch, tail, hkv, dh)
    return {
        "k_cents": jnp.zeros((batch, n_clusters, hkv, dh), dt),
        "v_cents": jnp.zeros((batch, n_clusters, hkv, dh), dt),
        "counts": jnp.zeros((batch, n_clusters, hkv), jnp.float32),
        "k_tail": jnp.zeros(tail_shape, dt),
        "v_tail": jnp.zeros(tail_shape, dt),
        # centroids summarize positions [0, cov); tail is exact for
        # [cov, t) — the partition makes compaction loss-free at the
        # ring-eviction boundary
        "cov": jnp.zeros((batch,), jnp.int32),
    }


# The per-SLOT summary state of a clustered cache leaf: everything a
# slot owns beyond its tail-ring payload.  This is exactly the state the
# prefix-sharing admission path snapshots at chunk boundaries and
# restores into a fresh slot (runtime/prefix_cache.py) — the tail bytes
# themselves are shared at block granularity through the pool instead.
CLUSTERED_SLOT_KEYS = ("k_cents", "v_cents", "counts", "cov")

USE_CLUSTERED_KERNEL = True  # Pallas fused path (interpret mode off-TPU)


def attn_decode_clustered(p, x, cfg: ModelConfig, *, cache, t,
                          kv_repeat: int = 1, use_kernel: bool = None,
                          chunk_len=None):
    """Attention over [median centroids ⊕ exact tail ring] — one token per
    slot (decode), or mixed-mode with a prompt chunk in flight.

    Centroid c with m keys gets a +log(m) logit bias (clustered-attention
    estimator).  The new keys/values are written into the tail ring at
    position % tail; centroid refresh happens outside the step (runtime).
    ``t`` may be scalar or per-slot (B,): the slot's cache length BEFORE
    this step.  Tail entries at positions < cov are already summarized by
    centroids and masked out (no double counting).

    Mixed mode (``chunk_len`` (B,) with x (B, L, d)): slot rows [0,
    chunk_len) are consecutive prompt positions t..t+chunk_len-1; their
    K/V go into the ring before scoring, so intra-chunk causal attention
    falls out of the ring mask.  Decode slots ride along with chunk_len 1.
    Dispatches to the fused Pallas ``clustered_decode`` kernel."""
    b, l = x.shape[0], x.shape[1]
    tb = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    chunked = chunk_len is not None
    cl = (jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
          if chunked else jnp.ones((b,), jnp.int32))
    ri = jnp.arange(l)[None, :]                           # (1, L)
    positions = tb[:, None] + ri
    q, k, v = _qkv(p, x, cfg, positions, "G", kv_repeat)
    tail = cache["k_tail"].shape[1]
    # masked chunk rows write out of range (dropped)
    slot = jnp.where(ri < cl[:, None], jnp.mod(positions, tail), tail)
    rows = jnp.arange(b)[:, None]
    k_tail = cache["k_tail"].at[rows, slot].set(
        k.astype(cache["k_tail"].dtype), mode="drop")
    v_tail = cache["v_tail"].at[rows, slot].set(
        v.astype(cache["v_tail"].dtype), mode="drop")
    cov = jnp.broadcast_to(jnp.asarray(cache.get("cov", 0), jnp.int32), (b,))

    hq = cfg.n_heads
    hkv = cache["k_tail"].shape[2]
    g = hq // hkv
    scale = _scale(cfg)
    if use_kernel is None:
        use_kernel = USE_CLUSTERED_KERNEL

    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.clustered_decode(
            q if chunked else q[:, 0],
            cache["k_cents"], cache["v_cents"], cache["counts"],
            k_tail, v_tail, tb, cov, cl, scale=scale,
            softcap=cfg.attn_logit_softcap)
        out = out.reshape(b, l, hkv, g, cfg.head_dim)
    else:
        qh = q.astype(jnp.float32).reshape(b, l, hkv, g, -1)
        s_c = jnp.einsum("blhgd,bchd->bhlgc", qh,
                         cache["k_cents"].astype(jnp.float32)) * scale
        s_c = _softcap(s_c, cfg.attn_logit_softcap)
        cnt = cache["counts"].transpose(0, 2, 1)[:, :, None, None, :]
        s_c = jnp.where(cnt > 0, s_c + jnp.log(jnp.maximum(cnt, 1e-9)), NEG)

        s_t = jnp.einsum("blhgd,bshd->bhlgs", qh,
                         k_tail.astype(jnp.float32)) * scale
        s_t = _softcap(s_t, cfg.attn_logit_softcap)
        pos = ring_slot_positions(tail, tb + cl)                 # (B, R)
        qpos1 = tb[:, None] + ri + 1                             # (B, L)
        ok = ((pos[:, None, :] >= 0)
              & (pos[:, None, :] < qpos1[:, :, None])
              & (pos[:, None, :] >= cov[:, None, None])
              & (ri < cl[:, None])[:, :, None])                  # (B, L, R)
        s_t = jnp.where(ok[:, None, :, None, :], s_t, NEG)

        s = jnp.concatenate([s_c, s_t], axis=-1)
        m = s.max(-1, keepdims=True)
        pw = jnp.exp(s - m)
        pw = pw / jnp.maximum(pw.sum(-1, keepdims=True), 1e-30)
        nc = cache["k_cents"].shape[1]
        out = (jnp.einsum("bhlgc,bchd->blhgd", pw[..., :nc],
                          cache["v_cents"].astype(jnp.float32))
               + jnp.einsum("bhlgs,bshd->blhgd", pw[..., nc:],
                            v_tail.astype(jnp.float32)))
    # under mesh serving the per-head context is model-sharded; gather heads
    # to a replicated layout BEFORE the output projection so the wo
    # contraction sums all head dims in one (device-order-independent)
    # pass — keeps mesh decode bit-identical to single-device greedy
    out_flat = annotate(out.reshape(b, l, hq * cfg.head_dim),
                        "batch", "seq", None)
    y = out_flat.astype(x.dtype) @ p["wo"].astype(cdtype(cfg))
    new_cache = dict(cache, k_tail=k_tail, v_tail=v_tail)
    return y, new_cache


def attn_decode_clustered_packed(p, x, cfg: ModelConfig, *, cache,
                                 row_slot, row_pos, row_tw, block_tables,
                                 block_size: int, kv_repeat: int = 1,
                                 row_wlo=None):
    """Paged clustered-KV attention over packed ragged rows.

    x (N, 1, d): one embedding per real (slot, position) pair this step —
    every decode slot's pending token ⊕ each admitting slot's prompt-chunk
    rows, padded only to the per-shard row bucket (compute ∝ real tokens,
    PagedAttention-style).  row_slot (N,) physical slot; row_pos (N,) the
    row's absolute position (−1 ⇒ padding row, output garbage by
    contract); row_tw (N,) the row's slot ring watermark t + chunk_len
    (all of a chunk's rows are written before any row scores, so
    intra-chunk causality falls out of the per-row position mask exactly
    as in the dense mixed launch); block_tables (B, T) global physical
    block ids — every entry valid, with blocks being *written* this step
    freshly allocated OR copy-on-write-owned by the engine (a sanitized
    dead-block alias, or a block another slot still references, would
    corrupt its true owner: kv_pool.ensure enforces ref == 1 before any
    row's write lands).

    Prefix sharing needs no change here: a shared prefix is just several
    table rows pointing at the same physical blocks, and a slot seeded
    mid-prompt (fed = F tokens reused, cov from the shared frontier)
    feeds its first row at position F like any other chunk row — the
    gather/mask math is identical, which is what keeps shared-admission
    greedy tokens bit-identical to unshared serving.

    The tail write scatters each row's K/V into its slot's pool block at
    the ring offset the dense path would use, so the paged cache holds
    bit-identical live bytes and greedy outputs match the dense engine
    exactly."""
    n = x.shape[0]
    positions = row_pos[:, None]                          # (N, 1)
    q, k, v = _qkv(p, x, cfg, positions, "G", kv_repeat)
    k, v = k[:, 0], v[:, 0]                               # (N, Hkv, Dh)
    t_blocks = block_tables.shape[1]
    ring = t_blocks * block_size
    nb = cache["k_tail"].shape[0]
    row_bt = jnp.take(block_tables, row_slot, axis=0)     # (N, T)
    roff = jnp.mod(row_pos, ring)
    blk = jnp.take_along_axis(row_bt, (roff // block_size)[:, None],
                              axis=1)[:, 0]
    valid = row_pos >= 0
    blk = jnp.where(valid, blk, nb)                       # pad rows drop
    off = roff % block_size
    k_pool = cache["k_tail"].at[blk, off].set(
        k.astype(cache["k_tail"].dtype), mode="drop")
    v_pool = cache["v_tail"].at[blk, off].set(
        v.astype(cache["v_tail"].dtype), mode="drop")

    qpos1 = jnp.where(valid, row_pos + 1, 0)
    row_cov = jnp.take(cache["cov"], row_slot, axis=0)
    if row_wlo is None:
        # no per-row retention window: the cov frontier is the only
        # lower bound (zeros keep the kernel mask bit-identical)
        row_wlo = jnp.zeros_like(qpos1)
    hq = cfg.n_heads
    from repro.kernels import ops as kops
    out = kops.paged_clustered_decode(
        q[:, 0], cache["k_cents"], cache["v_cents"], cache["counts"],
        k_pool, v_pool, row_slot, row_bt, qpos1, row_tw, row_cov,
        row_wlo=row_wlo, scale=_scale(cfg), softcap=cfg.attn_logit_softcap)
    # same head-gather-before-wo rule as the dense clustered path
    out_flat = annotate(out.reshape(n, 1, hq * cfg.head_dim),
                        "batch", "seq", None)
    y = out_flat.astype(x.dtype) @ p["wo"].astype(cdtype(cfg))
    new_cache = dict(cache, k_tail=k_pool, v_tail=v_pool)
    return y, new_cache


def attn_decode_window_packed(p, x, cfg: ModelConfig, *, cache, row_slot,
                              row_pos, row_cidx, width: int,
                              kv_repeat: int = 1):
    """Sliding-window ('L') attention over packed ragged rows.

    The local-layer twin of ``attn_decode_clustered_packed``: the paged
    engine packs one row per real (slot, position) pair, but local rings
    stay dense per slot — ``cache`` is the ordinary {'k','v'} (B, W, Hkv,
    Dh) ring, never pool-backed (WindowRetention's retirement is virtual:
    a position dies by falling out of the window, storage is reclaimed by
    the ring overwrite itself).

    ``row_cidx`` (N,) is each row's index within its admission chunk
    (decode rows 0) and ``width`` the static max chunk length this launch:
    rows commit in ``row_cidx`` order — scatter the K/V of every row at
    chunk index jj into its slot's ring, gather, score at watermark
    row_pos+1 — which reproduces the blocking engine's one-token-at-a-time
    window schedule exactly (two rows of one slot never share a cidx, so
    each scatter round is conflict-free)."""
    n = x.shape[0]
    window = cfg.sliding_window
    positions = row_pos[:, None]                          # (N, 1)
    q, k, v = _qkv(p, x, cfg, positions, "L", kv_repeat)
    k, v = k[:, 0], v[:, 0]                               # (N, Hkv, Dh)
    sc = cache["k"].shape[1]
    valid = row_pos >= 0
    kc, vc = cache["k"], cache["v"]
    out = jnp.zeros((n, cfg.n_heads, cfg.head_dim), jnp.float32)
    for jj in range(width):
        sel = valid & (row_cidx == jj)
        slot_w = jnp.where(sel, jnp.mod(row_pos, sc), sc)
        kc = kc.at[row_slot, slot_w].set(k.astype(kc.dtype), mode="drop")
        vc = vc.at[row_slot, slot_w].set(v.astype(vc.dtype), mode="drop")
        kcg = jnp.take(kc, row_slot, axis=0)              # (N, W, Hkv, Dh)
        vcg = jnp.take(vc, row_slot, axis=0)
        out_jj = decode_attention(q[:, 0], kcg, vcg, t=row_pos + 1,
                                  scale=_scale(cfg), window=window,
                                  softcap=cfg.attn_logit_softcap,
                                  ring=True)
        out = jnp.where(sel[:, None, None], out_jj.astype(jnp.float32),
                        out)
    # same head-gather-before-wo rule as the clustered packed path
    out_flat = annotate(out.reshape(n, 1, -1), "batch", "seq", None)
    y = out_flat.astype(x.dtype) @ p["wo"].astype(cdtype(cfg))
    return y, dict(cache, k=kc, v=vc)


def attn_decode(p, x, cfg: ModelConfig, *, layer_kind: str, cache, t,
                kv_repeat: int = 1, chunk_len=None):
    """x (B, 1, d) decode, or (B, L, d) mixed-mode with per-slot
    ``chunk_len`` (B,) valid rows (chunked prefill interleaved with
    decode); cache {'k','v'} (B, Sc, Hkv, Dh); t scalar int32 or a
    per-slot (B,) vector: the slot's cache length BEFORE this step."""
    if "k_cents" in cache:
        return attn_decode_clustered(p, x, cfg, cache=cache, t=t,
                                     kv_repeat=kv_repeat,
                                     chunk_len=chunk_len)
    b, l = x.shape[0], x.shape[1]
    tb = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    chunked = chunk_len is not None
    cl = (jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
          if chunked else jnp.ones((b,), jnp.int32))
    ri = jnp.arange(l)[None, :]
    positions = tb[:, None] + ri                          # (B, L)
    q, k, v = _qkv(p, x, cfg, positions, layer_kind, kv_repeat)
    window = cfg.sliding_window if layer_kind == "L" else None
    sc = cache["k"].shape[1]
    if chunked and window is not None:
        # WindowRetention's staging rule: writing a whole chunk into a
        # W-sized ring at once would overwrite positions t+i-W that are
        # still inside earlier rows' attention windows — there is no
        # coverage frontier here to absorb them first (unlike the
        # clustered cache).  So rows commit sequentially: write row i at
        # its ring slot, then score it at watermark t+i+1, exactly the
        # schedule the blocking engine runs one decode step at a time.
        # A row's overwrite victim (position t+i-W) is already outside
        # the window of every later row, so nothing is lost.
        new_cache = dict(cache)
        outs = []
        for i in range(l):
            slot_i = jnp.where(i < cl, jnp.mod(tb + i, sc), sc)[:, None]
            kc, vc = _cache_write(new_cache, k[:, i:i + 1], v[:, i:i + 1],
                                  slot_i)
            new_cache = dict(new_cache, k=kc, v=vc)
            k_read, v_read = _cache_read(new_cache, cfg)
            outs.append(decode_attention(
                q[:, i], k_read, v_read, t=tb + i + 1, scale=_scale(cfg),
                window=window, softcap=cfg.attn_logit_softcap, ring=True))
        out = jnp.stack(outs, axis=1)
        out_flat = annotate(out.reshape(b, l, -1), "batch", "seq", None)
        return out_flat @ p["wo"].astype(cdtype(cfg)), new_cache
    slot = jnp.mod(positions, sc) if window \
        else jnp.minimum(positions, sc - 1)
    slot = jnp.where(ri < cl[:, None], slot, sc)          # drop masked rows
    kc, vc = _cache_write(cache, k, v, slot)
    new_cache = dict(cache, k=kc, v=vc)
    k_read, v_read = _cache_read(new_cache, cfg)
    if chunked:
        out = decode_attention(q, k_read, v_read, t=tb, chunk_len=cl,
                               scale=_scale(cfg), window=window,
                               softcap=cfg.attn_logit_softcap,
                               ring=window is not None)
    else:
        out = decode_attention(q[:, 0], k_read, v_read, t=tb + 1,
                               scale=_scale(cfg),
                               window=window,
                               softcap=cfg.attn_logit_softcap,
                               ring=window is not None)
    # same head-gather-before-wo rule as the clustered path (see above)
    out_flat = annotate(out.reshape(b, l, -1), "batch", "seq", None)
    y = out_flat @ p["wo"].astype(cdtype(cfg))
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder–decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(rng, cfg: ModelConfig, name: str = "xattn"):
    return init_attn(rng, cfg, name)


def cross_attn_apply(p, x, enc_kv, cfg: ModelConfig):
    """x (B, Sq, d); enc_kv = (k, v) precomputed from encoder output."""
    dt = cdtype(cfg)
    b, s, _ = x.shape
    hq, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dt)).reshape(b, s, hq, dh)
    k, v = enc_kv
    out = chunked_attention(q, k, v, causal=False, scale=_scale(cfg),
                            softcap=cfg.attn_logit_softcap)
    return out.reshape(b, s, -1) @ p["wo"].astype(dt)


def cross_kv(p, enc_out, cfg: ModelConfig):
    dt = cdtype(cfg)
    b, s, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(dt)).reshape(b, s, hkv, dh)
    v = (enc_out @ p["wv"].astype(dt)).reshape(b, s, hkv, dh)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank Q/KV with compressed-latent cache
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ModelConfig, name: str = "mla"):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": dense_init(rng_for(rng, name + "/wdq"), (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wuq": dense_init(rng_for(rng, name + "/wuq"),
                          (m.q_lora_rank, h * qd)),
        "wdkv": dense_init(rng_for(rng, name + "/wdkv"), (d, m.kv_lora_rank)),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wukv": dense_init(
            rng_for(rng, name + "/wukv"),
            (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim))),
        "wkr": dense_init(rng_for(rng, name + "/wkr"),
                          (d, m.qk_rope_head_dim)),
        "wo": dense_init(rng_for(rng, name + "/wo"), (h * m.v_head_dim, d)),
    }


def _mla_q(p, x, cfg: ModelConfig, positions):
    dt = cdtype(cfg)
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rms_head_norm(p["q_norm"], x @ p["wdq"].astype(dt), cfg.norm_eps)
    q = (cq @ p["wuq"].astype(dt)).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg: ModelConfig, positions):
    dt = cdtype(cfg)
    ckv = rms_head_norm(p["kv_norm"], x @ p["wdkv"].astype(dt), cfg.norm_eps)
    kpe = (x @ p["wkr"].astype(dt))[:, :, None, :]       # (B,S,1,rope)
    kpe = apply_rope(kpe, positions, cfg.rope_theta)[:, :, 0]
    return ckv, kpe


def mla_train(p, x, cfg: ModelConfig, *, positions, chunk_kv: int = 1024):
    """Expanded (training/prefill) form: materializes per-head K/V."""
    m = cfg.mla
    dt = cdtype(cfg)
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, kpe = _mla_latent(p, x, cfg, positions)
    kv = (ckv @ p["wukv"].astype(dt)).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kv[..., :m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = chunked_attention(q, k, v, causal=True, scale=scale,
                            chunk_kv=chunk_kv)
    return out.reshape(b, s, -1) @ p["wo"].astype(dt)


def init_cache_mla(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    m = cfg.mla
    dt = dtype or cdtype(cfg)
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dt),
        "kpe": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dt),
    }


def mla_prefill(p, x, cfg: ModelConfig, *, positions, max_seq: int,
                chunk_kv: int = 1024):
    y = mla_train(p, x, cfg, positions=positions, chunk_kv=chunk_kv)
    ckv, kpe = _mla_latent(p, x, cfg, positions)
    b, s = x.shape[0], x.shape[1]
    pad = max_seq - s
    cache = {
        "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
        "kpe": jnp.pad(kpe, ((0, 0), (0, pad), (0, 0))),
    }
    return y, cache


def mla_decode(p, x, cfg: ModelConfig, *, cache, t):
    """Absorbed decode: queries hit the latent cache directly — the cache
    holds only (c_kv, k_pe) per token (the paper-exact compressed cache)."""
    m = cfg.mla
    dt = cdtype(cfg)
    b = x.shape[0]
    h = cfg.n_heads
    tb = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    positions = tb[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)        # (B,1,H,·)
    ckv_new, kpe_new = _mla_latent(p, x, cfg, positions)
    rows = jnp.arange(b)
    ckv = cache["ckv"].at[rows, tb].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[rows, tb].set(
        kpe_new[:, 0].astype(cache["kpe"].dtype))

    wukv = p["wukv"].astype(dt).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wuk = wukv[..., :m.qk_nope_head_dim]                 # (r, H, nope)
    wuv = wukv[..., m.qk_nope_head_dim:]                 # (r, H, v)

    # absorb W_uk into the query: q' (B, H, r)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], wuk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhe,bse->bhs", q_rope[:, 0].astype(jnp.float32),
                      kpe.astype(jnp.float32))) * scale
    pos = jnp.arange(ckv.shape[1])
    s = jnp.where((pos[None, :] < (tb + 1)[:, None])[:, None, :], s, NEG)
    pmax = s.max(-1, keepdims=True)
    w = jnp.exp(s - pmax)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    ctx = jnp.einsum("bhs,bsr->bhr", w, ckv.astype(jnp.float32))  # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(dt), wuv)
    y = out.reshape(b, 1, -1) @ p["wo"].astype(dt)
    return y, {"ckv": ckv, "kpe": kpe}
