"""Pure oracles for the Pallas kernels (numpy; independent implementations).

Semantics pinned here:
  * median = LOWER median (1-based rank ceil(n/2)) — what the paper's
    majority tie-break ("output is 0 when N/2 or more inputs are 0") yields.
  * grouped medians operate on the fixed-point grid; since quantization is
    monotone it commutes with order statistics, so the float-level oracle is
    dequantize(quantize(lower_median)).
"""

from __future__ import annotations

import numpy as np


def lower_median_ref(x: np.ndarray, axis: int = 0) -> np.ndarray:
    """Lower median along ``axis``."""
    x = np.asarray(x)
    n = x.shape[axis]
    xs = np.sort(x, axis=axis)
    idx = (n + 1) // 2 - 1
    return np.take(xs, idx, axis=axis)


def weighted_lower_median_ref(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Weighted lower median along axis 0 (integer weights == repetition).

    x: (N, D), w: (N,) non-negative ints.  Returns (D,).
    Lower median of the multiset where x[i] appears w[i] times: smallest v
    with cumulative weight >= ceil(W/2).
    """
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    n, d = x.shape
    out = np.zeros((d,), np.float64)
    W = w.sum()
    target = np.ceil(W / 2.0)
    for j in range(d):
        order = np.argsort(x[:, j], kind="stable")
        cum = np.cumsum(w[order])
        pos = np.searchsorted(cum, target, side="left")
        out[j] = x[order[min(pos, n - 1)], j]
    return out


def grouped_median_ref(x: np.ndarray, assign: np.ndarray, k: int,
                       fill: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Per-cluster lower medians.  x (N, D), assign (N,) → ((k, D), counts).

    Empty clusters take ``fill`` rows (or 0).
    """
    x = np.asarray(x)
    n, d = x.shape
    med = np.zeros((k, d), x.dtype)
    counts = np.zeros((k,), np.int64)
    for c in range(k):
        m = assign == c
        counts[c] = m.sum()
        if counts[c] == 0:
            med[c] = 0.0 if fill is None else fill[c]
        else:
            med[c] = lower_median_ref(x[m], axis=0)
    return med, counts


def distance_argmin_ref(x: np.ndarray, cents: np.ndarray, metric: str = "l2"
                        ) -> tuple[np.ndarray, np.ndarray]:
    """x (N, D), cents (K, D) → (assign (N,), mindist (N,)).
    L2 distances are squared."""
    x = np.asarray(x, np.float32)
    cents = np.asarray(cents, np.float32)
    if metric == "l2":
        d = ((x[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
    elif metric == "l1":
        d = np.abs(x[:, None, :] - cents[None, :, :]).sum(-1)
    else:
        raise ValueError(metric)
    return d.argmin(1).astype(np.int32), d.min(1).astype(np.float32)
