"""Pallas TPU kernel: VMEM-resident bit-serial grouped median.

This is the paper's in-situ accelerator mapped to the TPU memory hierarchy:
the fixed-point tile is read from HBM into VMEM **once** and the whole
B-bit majority scan runs against the resident tile — the analogue of the
RRAM arrays computing the majority vote in place instead of streaming the
operands to the core B times.

Layout (per grid instance):
  u      (N, TD)  uint32  — unsigned-ordered fixed-point data, full point
                            axis resident (the paper's "limited-size array";
                            the VMEM capacity plays the role of the array
                            size limit; ops.py falls back to the two-level
                            reduction-tree path above the VMEM limit)
  assign (N, 1)   int32   — cluster ids (the paper's P/I inclusion predicate)
  w      (N, 1)   f32     — per-point weights (mask / merge counts)
  med    (K, TD)  uint32  — per-cluster medians (output)

Grid: (D // TD,).  K is a compile-time constant.  Per bit the vote count is
a one-hot matmul (MXU): cnt1[k, d] = Σ_i onehot[i, k] · eff[i, d]; the
broadcast of the majority decision back to the points is a second matmul
(avoids dynamic gather, which Mosaic dislikes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, assign_ref, w_ref, med_ref, *, k: int, bits: int):
    u = u_ref[...]                      # (N, TD) uint32
    assign = assign_ref[...]            # (N, 1) int32
    w = w_ref[...]                      # (N, 1) f32
    n = u.shape[0]

    kids = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)       # (1, K)
    onehot01 = (assign == kids).astype(jnp.float32)             # (N, K)
    onehot = onehot01 * w                                       # weighted votes
    total = jnp.sum(onehot, axis=0)                             # (K,)

    active0 = jnp.ones(u.shape, jnp.float32)
    forced0 = jnp.zeros(u.shape, jnp.float32)
    med0 = jnp.zeros(med_ref.shape, jnp.uint32)

    def body(i, carry):
        active, forced, med = carry
        b = (jnp.uint32(bits - 1) - i.astype(jnp.uint32))
        bit = (jax.lax.shift_right_logical(u, b) & jnp.uint32(1)
               ).astype(jnp.float32)                            # (N, TD)
        eff = active * bit + (1.0 - active) * forced
        # vote count: (K, N) x (N, TD) on the MXU
        cnt1 = jax.lax.dot_general(
            onehot, eff, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (K, TD)
        mbit = (cnt1 * 2.0 > total[:, None]).astype(jnp.float32)  # (K, TD)
        med = med | jnp.where(
            mbit > 0.5,
            jax.lax.shift_left(jnp.uint32(1), b),
            jnp.uint32(0))
        # broadcast decision back to points: (N, K) x (K, TD)
        mper = jax.lax.dot_general(
            onehot01, mbit, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (N, TD)
        dev = active * jnp.abs(bit - mper)                       # 1 where minority
        forced = dev * bit + (1.0 - dev) * forced
        active = active * (1.0 - dev)
        return active, forced, med

    _, _, med = jax.lax.fori_loop(0, bits, body, (active0, forced0, med0))
    med_ref[...] = med


def grouped_median_pallas(u, assign, weights, k: int, *, bits: int = 32,
                          d_block: int = 128, interpret: bool = False):
    """u (N, D) uint32, assign (N,) int32, weights (N,) f32 → (k, D) uint32.

    The full point axis is VMEM-resident; the grid tiles D only.  Callers
    above the VMEM budget use the two-level reduction-tree path in ops.py.
    """
    n, d = u.shape
    pad_d = (-d) % d_block
    if pad_d:
        u = jnp.pad(u, ((0, 0), (0, pad_d)))
    dp = d + pad_d
    assign2 = assign.reshape(n, 1).astype(jnp.int32)
    w2 = weights.reshape(n, 1).astype(jnp.float32)

    grid = (dp // d_block,)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d_block), lambda j: (0, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((n, 1), lambda j: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, 1), lambda j: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((k, d_block), lambda j: (0, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((k, dp), jnp.uint32),
        interpret=interpret,
    )(u, assign2, w2)
    return out[:, :d]
