"""Pallas TPU kernel: blocked distance + argmin (assignment step).

The clustering assignment step (closest-centroid search) is the other
compute hot-spot of Algorithm 1.  L2 uses the MXU expansion
‖x‖² − 2·x·cᵀ + ‖c‖²; L1 loops over centroids on the VPU (no (N, K, D)
intermediate is ever materialized).

Layout (per grid instance over N tiles):
  x     (TN, D)  f32
  cents (K, D)   f32  (resident, replicated across instances)
  out   assign (TN, 1) int32, mindist (TN, 1) f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_l2(x_ref, c_ref, a_ref, m_ref):
    x = x_ref[...]                          # (TN, D)
    c = c_ref[...]                          # (K, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # (TN, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                  # (1, K)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TN, K)
    dist = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)
    a_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None]
    m_ref[...] = jnp.min(dist, axis=1)[:, None]


def _kernel_l1(x_ref, c_ref, a_ref, m_ref, *, k: int):
    x = x_ref[...]                          # (TN, D)
    c = c_ref[...]                          # (K, D)
    tn = x.shape[0]

    def body(i, carry):
        best_d, best_i = carry
        di = jnp.sum(jnp.abs(x - c[i][None, :]), axis=1)   # (TN,)
        better = di < best_d
        return (jnp.where(better, di, best_d),
                jnp.where(better, i, best_i))

    best_d0 = jnp.full((tn,), jnp.inf, jnp.float32)
    best_i0 = jnp.zeros((tn,), jnp.int32)
    best_d, best_i = jax.lax.fori_loop(0, k, body, (best_d0, best_i0))
    a_ref[...] = best_i[:, None]
    m_ref[...] = best_d[:, None]


def distance_argmin_pallas(x, cents, *, metric: str = "l2",
                           n_block: int = 1024, interpret: bool = False):
    """x (N, D) f32, cents (K, D) f32 → (assign (N,), mindist (N,))."""
    n, d = x.shape
    k = cents.shape[0]
    pad_n = (-n) % n_block
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    np_ = n + pad_n
    grid = (np_ // n_block,)

    kern = (_kernel_l2 if metric == "l2"
            else functools.partial(_kernel_l1, k=k))
    assign, mind = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_block, d), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((k, d), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((n_block, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((n_block, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), cents.astype(jnp.float32))
    return assign[:n, 0], mind[:n, 0]
