"""Pallas TPU kernel: paged clustered-KV decode over packed ragged rows.

The dense ``clustered_decode`` launch pays ``slots × width`` query rows
(``width`` = the prefill chunk during mixed steps) and reads each slot's
tail ring from a contiguous per-slot buffer.  This kernel removes both
costs for the paged engine:

  * **packed ragged rows** — the grid's first dimension is the number of
    *real* (slot, position) pairs this step (every active decode slot's
    one token ⊕ the admitting slots' chunk rows), padded only to the
    per-shard row bucket.  Compute scales with real tokens, not
    ``slots × width`` (the PagedAttention-style ragged batch);
  * **block-table gathers** — each row's tail ring is scattered across
    fixed-size pool blocks; the row's block table (scalar-prefetched, so
    the index maps can steer the DMA) walks the grid's trailing dimension
    and stages one block per step into a VMEM scratch ring, then the last
    step runs the identical [centroids ⊕ ring] joint softmax as the dense
    kernel.

Bit-identity with the dense kernel is deliberate: the staged scratch ring
reproduces the dense kernel's ``(R, Dh)`` tail operand exactly (same f32
casts, same dot_general contractions, same mask order), so the paged
engine's greedy tokens match the dense engine's bit for bit — pinned in
tests.

Layout (grid = (N rows, Hkv, T tail blocks); scalar prefetch: row block
table (N, T) and row→slot map (N,)):
  qpos1, tw, cov  (1,)  SMEM  — per row: query position + 1 (0 ⇒ padding
                                row, fully masked), slot ring watermark
                                (t + chunk_len), coverage frontier
  q        (1, 1, G, Dh)  VMEM  — this row × kv-head's query
  k_cents  (1, C, 1, Dh)  VMEM  — gathered per row via the slot map
  counts   (1, 1, C)      VMEM  — pre-transposed (B, Hkv, C)
  k_pool   (1, bs, 1, Dh) VMEM  — one physical tail block per grid step,
                                  gathered via the block table
  out      (1, 1, G, Dh)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels.clustered_decode import (_SHARD_MAP_NO_CHECK,
                                            score_and_combine, shard_map)


def _kernel(bt_ref, slot_ref, qpos1_ref, tw_ref, cov_ref, wlo_ref, q_ref,
            kc_ref, vc_ref, cnt_ref, kp_ref, vp_ref, o_ref, kt_s, vt_s, *,
            bs: int, nblk: int, r: int, scale: float, softcap):
    j = pl.program_id(2)
    # stage this row's tail block j into the scratch ring at its ring
    # offsets [j*bs, (j+1)*bs) — after the last step the scratch holds the
    # same (R, Dh) f32 operand the dense kernel reads contiguously
    kt_s[pl.ds(j * bs, bs), :] = kp_ref[0, :, 0, :].astype(jnp.float32)
    vt_s[pl.ds(j * bs, bs), :] = vp_ref[0, :, 0, :].astype(jnp.float32)

    @pl.when(j == nblk - 1)
    def _compute():
        qpos1 = qpos1_ref[0]
        tw = tw_ref[0]
        cov = cov_ref[0]
        wlo = wlo_ref[0]
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, Dh)
        kc = kc_ref[0, :, 0].astype(jnp.float32)             # (C, Dh)
        vc = vc_ref[0, :, 0].astype(jnp.float32)
        cnt = cnt_ref[0, 0].astype(jnp.float32)              # (C,)

        row_ok = qpos1 > 0                                   # padding row?

        # ring offset s claims position s while tw <= R, else the wrapped
        # window — identical mask math to the dense kernel, with the
        # row's own absolute position (qpos1 - 1) as the causal bound.
        # ``wlo`` is the row's retention window lower bound (0 under
        # FrontierRetention — cov alone gates; t - window under
        # WindowRetention), masked alongside cov so a retired-but-not-yet
        # -overwritten ring entry can never score
        sl = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
        wrapped = tw - r + jnp.mod(sl - tw, r)
        pos = jnp.where(tw <= r, sl, wrapped)                # (1, R)
        ok = ((pos >= 0) & (pos < qpos1) & (pos >= cov) & (pos >= wlo)
              & row_ok)

        # the scoring body is SHARED with the dense kernel — the staged
        # scratch ring is its (R, Dh) tail operand, so the paged engine's
        # outputs are bit-identical to the dense engine's per row
        out = score_and_combine(q, kc, vc, cnt, kt_s[:], vt_s[:],
                                row_ok, ok, scale=scale, softcap=softcap)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_clustered_decode_pallas(q, k_cents, v_cents, counts, k_pool,
                                  v_pool, row_slot, row_bt, qpos1, tw, cov,
                                  wlo=None, *, scale: float, softcap=None,
                                  interpret: bool | None = None):
    """q (N, Hq, Dh) packed rows; k/v_cents (B, C, Hkv, Dh); counts
    (B, C, Hkv); k/v_pool (nb, bs, Hkv, Dh) block pools; row_slot (N,)
    slot per row; row_bt (N, T) physical block per (row, ring block) —
    every entry must be a valid pool index (the caller maps unallocated
    blocks to a garbage block whose offsets the masks exclude); qpos1
    (N,) = row position + 1 (0 for padding rows); tw (N,) slot ring
    watermark t + chunk_len; cov (N,) coverage frontier; wlo (N,) the
    row's retention window lower bound (None/zeros ⇒ frontier-only
    masking, bit-identical to before).  → (N, Hq, Dh); padding rows
    return a degenerate uniform the caller must discard."""
    if interpret is None:
        from repro.kernels.ops import interpret_default
        interpret = interpret_default()
    n, hq, dh = q.shape
    c = k_cents.shape[1]
    hkv = k_cents.shape[2]
    g = hq // hkv
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    t_blocks = row_bt.shape[1]
    r = t_blocks * bs
    qh = q.reshape(n, hkv, g, dh)
    cnt_t = counts.transpose(0, 2, 1)                        # (B, Hkv, C)
    row_slot = jnp.asarray(row_slot, jnp.int32)
    row_bt = jnp.asarray(row_bt, jnp.int32)
    qpos1 = jnp.asarray(qpos1, jnp.int32)
    tw = jnp.asarray(tw, jnp.int32)
    cov = jnp.asarray(cov, jnp.int32)
    if wlo is None:
        wlo = jnp.zeros_like(qpos1)
    wlo = jnp.asarray(wlo, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # row_bt, row_slot
        grid=(n, hkv, t_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, h, j, bt, sl: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h, j, bt, sl: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h, j, bt, sl: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h, j, bt, sl: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda i, h, j, bt, sl: (i, h, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh),
                         lambda i, h, j, bt, sl: (sl[i], 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh),
                         lambda i, h, j, bt, sl: (sl[i], 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, c), lambda i, h, j, bt, sl: (sl[i], h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bs, 1, dh),
                         lambda i, h, j, bt, sl: (bt[i, j], 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bs, 1, dh),
                         lambda i, h, j, bt, sl: (bt[i, j], 0, h, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda i, h, j, bt, sl: (i, h, 0, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((r, dh), jnp.float32),
            pltpu.VMEM((r, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, bs=bs, nblk=t_blocks, r=r,
                               scale=scale, softcap=softcap)
    call_kwargs = dict(interpret=interpret)
    if not interpret:
        # rows/heads may split across cores (each core's scratch ring is
        # private); the tail-block walk must stay sequential per (row,
        # head) so the staging completes before the compute step
        call_kwargs["compiler_params"] = dict(mosaic=dict(
            dimension_semantics=("parallel", "parallel", "arbitrary")))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, hkv, g, dh), q.dtype),
        **call_kwargs,
    )(row_bt, row_slot, qpos1, tw, cov, wlo, qh, k_cents, v_cents, cnt_t,
      k_pool, v_pool)
    return out.reshape(n, hq, dh)


def _fold_axis_index(axes, mesh):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def paged_clustered_decode_shardmap(q, k_cents, v_cents, counts, k_pool,
                                    v_pool, row_slot, row_bt, qpos1, tw,
                                    cov, wlo, *, mesh, data_axes,
                                    model_axes, scale: float, softcap=None,
                                    interpret: bool = False):
    """Dispatch the paged kernel once per mesh shard.

    Rows, slots, and the block pool all partition over ``data``
    (contiguous leading-axis shards, so a slot's blocks live on its own
    shard by construction — see runtime/kv_pool.py); kv-head grid cells
    partition over ``model``.  Block ids and slot ids arrive global and
    are rebased to the local shard inside the island, so the engine keeps
    a single flat table."""
    d, m = data_axes, model_axes

    def body(q, kc, vc, cnt, kp, vp, rs, rbt, qp1, tw_, cov_, wlo_):
        if d:
            di = _fold_axis_index(d, mesh)
            rs = rs - di * kc.shape[0]
            rbt = rbt - di * kp.shape[0]
        return paged_clustered_decode_pallas(
            q, kc, vc, cnt, kp, vp, rs, rbt, qp1, tw_, cov_, wlo_,
            scale=scale, softcap=softcap, interpret=interpret)

    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(d, m, None),        # q        (N, Hq, Dh)
            P(d, None, m, None),  # k_cents  (B, C, Hkv, Dh)
            P(d, None, m, None),  # v_cents
            P(d, None, m),        # counts   (B, C, Hkv)
            P(d, None, m, None),  # k_pool   (nb, bs, Hkv, Dh)
            P(d, None, m, None),  # v_pool
            P(d),                 # row_slot (N,)
            P(d, None),           # row_bt   (N, T)
            P(d),                 # qpos1    (N,)
            P(d),                 # tw       (N,)
            P(d),                 # cov      (N,)
            P(d),                 # wlo      (N,) retention window floor
        ),
        out_specs=P(d, m, None),
        **_SHARD_MAP_NO_CHECK,
    )
    return f(q, k_cents, v_cents, counts, k_pool, v_pool, row_slot, row_bt,
             qpos1, tw, cov, wlo)
