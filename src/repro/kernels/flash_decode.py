"""Pallas TPU kernel: flash-decode — one-token attention over a long KV
cache with VMEM chunking and an online softmax carried across grid steps.

The TPU grid executes sequentially per core, so the (m, l, acc) flash
state lives in VMEM scratch across the chunk dimension: the KV cache
streams HBM→VMEM exactly once, at chunk granularity, and the (G, Dh)
accumulator never leaves VMEM — the same "operands stay resident, move
one reduction step at a time" structure as the bit-serial median kernel.

Layout (grid = (B, Hkv, S/C)):
  t     (1, 1)  SMEM  — valid cache length (positions ≥ t are masked)
  q     (1, 1, G, Dh)  — this kv-head's query group
  k, v  (1, C, 1, Dh)  — one cache chunk for this (batch, kv-head)
  out   (1, 1, G, Dh)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            chunk: int, n_chunks: int, scale: float, softcap):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (C, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (C, Dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    s = jnp.where(kpos < t_ref[0, 0], s, NEG)            # (G, C)

    m_old = m_s[...]                                     # (G, 1)
    m_new = jnp.maximum(m_old, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_old - m_new)
    l_s[...] = l_s[...] * corr + p.sum(-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ci == n_chunks - 1)
    def _fin():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, t, *, scale: float, softcap=None,
                        chunk: int = 512, interpret: bool = False):
    """q (B, Hq, Dh), k/v (B, S, Hkv, Dh), t scalar int32 (valid length)
    → (B, Hq, Dh).  Exact (full-cache) decode attention."""
    b, hq, dh = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    qh = q.reshape(b, hkv, g, dh)
    t_arr = jnp.full((1, 1), t, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc, scale=scale,
                          softcap=softcap),
        grid=(b, hkv, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda i, h, c: (i, h, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, 1, dh), lambda i, h, c: (i, c, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, 1, dh), lambda i, h, c: (i, c, h, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda i, h, c: (i, h, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(t_arr, qh, k, v)
    return out.reshape(b, hq, dh)
