"""Pallas TPU kernel: fused clustered-KV decode attention, mixed-mode.

Attention over [median centroids ⊕ exact tail ring] — the clustered-
attention estimator of the paper's memory manager — in a single
VMEM-resident pass per (batch, kv-head) grid instance:

  * centroid logits get the +log(count) bias (a centroid standing for m
    keys receives the softmax mass of m identical-score keys); empty
    clusters (count == 0) are masked,
  * tail logits are masked by ring validity (position in [cov, qpos]; the
    positions below ``cov`` are already summarized by centroids, so the
    partition is exact — nothing double-counted, nothing lost),
  * one joint softmax over the concatenated score row and two MXU
    combines against v_cents / v_tail.

**Mixed-mode launch** (chunked prefill interleaved with decode): every
slot carries up to L query rows.  Decode slots use one row (their next
token); a slot admitting a prompt carries a whole chunk whose K/V were
written into its tail ring *before* the launch, so intra-chunk causal
attention falls out of the same ring mask — query row i (absolute
position t + i) sees ring positions < t + i + 1.  Per-slot ``t`` /
``cov`` / ``chunk_len`` vectors come in through SMEM, so decode slots at
different depths and an in-flight prefill chunk score in one launch.
Caller invariant: the chunk's pre-write overwrites ring positions
t+i-R, so ``cov >= t + chunk_len - R`` must hold (the engine's
absorb_chunk pre-pass guarantees it) — the overwritten positions are
then summarized by centroids and nothing is lost.

Layout (grid = (B, Hkv)):
  t, cov, chunk_len  (1,)  SMEM  — slot valid length / coverage / rows
  q        (1, 1, L, G, Dh)  VMEM  — this kv-head's query rows
  k_cents  (1, C, 1, Dh)     VMEM     v_cents same
  counts   (1, 1, C)         VMEM  — pre-transposed (B, Hkv, C)
  k_tail   (1, R, 1, Dh)     VMEM     v_tail same (ring order, chunk
                                      rows already written)
  out      (1, 1, L, G, Dh)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map
except ImportError:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map

# the replication-check kwarg was renamed check_rep -> check_vma when
# shard_map moved to the top-level namespace; resolve it by signature so
# both APIs disable the check (the Pallas call has no replication rule)
import inspect as _inspect

_SHARD_MAP_NO_CHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(shard_map).parameters
    else {"check_rep": False})

NEG = -1e30


def score_and_combine(q, kc, vc, cnt, kt, vt, row_ok, tail_ok, *,
                      scale: float, softcap):
    """Shared [centroids ⊕ tail ring] joint-softmax body.

    q (rows, Dh) f32 query rows; kc/vc (C, Dh); cnt (C,); kt/vt (R, Dh);
    row_ok broadcastable to (rows, C) — masks invalid/padding rows;
    tail_ok (rows, R) — the full ring validity mask (position window,
    coverage frontier, and row validity pre-combined by the caller).
    Returns (rows, Dh) f32.

    Both the dense ``clustered_decode`` kernel and the paged
    ``paged_clustered_decode`` kernel call THIS function for their
    scoring — bit-identity between the two engines is a hard invariant
    (the paged engine's tokens must equal the dense engine's), so the
    math must never fork."""
    s_c = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_c = jnp.tanh(s_c / softcap) * softcap
    cnt_row = cnt[None, :]                                   # (1, C)
    s_c = jnp.where((cnt_row > 0) & row_ok,
                    s_c + jnp.log(jnp.maximum(cnt_row, 1e-9)), NEG)

    s_t = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_t = jnp.tanh(s_t / softcap) * softcap
    s_t = jnp.where(tail_ok, s_t, NEG)

    m = jnp.maximum(s_c.max(-1, keepdims=True), s_t.max(-1, keepdims=True))
    p_c = jnp.exp(s_c - m)
    p_t = jnp.exp(s_t - m)
    lsum = p_c.sum(-1, keepdims=True) + p_t.sum(-1, keepdims=True)
    acc = (jax.lax.dot_general(p_c, vc, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(p_t, vt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32))
    return acc / jnp.maximum(lsum, 1e-30)


def _kernel(t_ref, cov_ref, len_ref, q_ref, kc_ref, vc_ref, cnt_ref, kt_ref,
            vt_ref, o_ref, *, l: int, g: int, r: int, scale: float, softcap):
    t = t_ref[0]
    cov = cov_ref[0]
    cl = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32).reshape(l * g, -1)   # (L*G, Dh)
    kc = kc_ref[0, :, 0].astype(jnp.float32)                 # (C, Dh)
    vc = vc_ref[0, :, 0].astype(jnp.float32)
    cnt = cnt_ref[0, 0].astype(jnp.float32)                  # (C,)
    kt = kt_ref[0, :, 0].astype(jnp.float32)                 # (R, Dh)
    vt = vt_ref[0, :, 0].astype(jnp.float32)

    # query row i*g + j carries chunk index i → absolute position t + i
    li = jax.lax.broadcasted_iota(jnp.int32, (l * g, 1), 0) // g
    row_ok = li < cl

    # chunk rows sit in the ring already: tw = t + cl entries total.  Ring
    # slot s holds position s while tw <= R, else the wrapped window.
    sl = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    tw = t + cl
    wrapped = tw - r + jnp.mod(sl - tw, r)
    pos = jnp.where(tw <= r, sl, wrapped)                    # (1, R)
    qpos = t + li                                            # (L*G, 1)
    ok = (pos >= 0) & (pos < qpos + 1) & (pos >= cov) & row_ok

    out = score_and_combine(q, kc, vc, cnt, kt, vt, row_ok, ok,
                            scale=scale, softcap=softcap)
    o_ref[0, 0] = out.reshape(l, g, -1).astype(o_ref.dtype)


def clustered_decode_shardmap(q, k_cents, v_cents, counts, k_tail, v_tail,
                              t, cov, chunk_len=None, *, mesh, data_axes,
                              model_axes, scale: float, softcap=None,
                              interpret: bool = False):
    """Dispatch the Pallas kernel once per mesh shard.

    The kernel grid is (batch, kv-head) and every grid cell is independent,
    so a (data, model)-sharded launch is exact: each shard runs the same
    kernel on its local (B/d, Hkv/m) block — no collectives, and the
    existing interpret-mode CPU fallback applies per shard unchanged.

    ``data_axes`` / ``model_axes`` are the mesh axis tuples partitioning the
    batch / head dims (either may be None → replicated along that dim); the
    caller (kernels.ops) checks divisibility before choosing them.  t / cov
    / chunk_len must already be (B,) vectors so they shard with the batch.
    """
    b = q.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    cov = jnp.broadcast_to(jnp.asarray(cov, jnp.int32), (b,))
    if chunk_len is None:
        chunk_len = jnp.ones((b,), jnp.int32)
    chunk_len = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    qspec = P(data_axes, model_axes, None) if q.ndim == 3 else \
        P(data_axes, None, model_axes, None)
    d, m = data_axes, model_axes
    f = shard_map(
        functools.partial(clustered_decode_pallas, scale=scale,
                          softcap=softcap, interpret=interpret),
        mesh=mesh,
        in_specs=(
            qspec,                # q        (B, [L,] Hq, Dh)
            P(d, None, m, None),  # k_cents  (B, C, Hkv, Dh)
            P(d, None, m, None),  # v_cents
            P(d, None, m),        # counts   (B, C, Hkv)
            P(d, None, m, None),  # k_tail   (B, R, Hkv, Dh)
            P(d, None, m, None),  # v_tail
            P(d),                 # t        (B,)
            P(d),                 # cov      (B,)
            P(d),                 # chunk_len (B,)
        ),
        out_specs=qspec,
        **_SHARD_MAP_NO_CHECK,
    )
    return f(q, k_cents, v_cents, counts, k_tail, v_tail, t, cov, chunk_len)


def clustered_decode_pallas(q, k_cents, v_cents, counts, k_tail, v_tail,
                            t, cov, chunk_len=None, *, scale: float,
                            softcap=None, interpret: bool | None = None):
    """q (B, Hq, Dh) decode form, or (B, L, Hq, Dh) mixed form with
    per-slot ``chunk_len`` (B,) valid rows; k/v_cents (B, C, Hkv, Dh);
    counts (B, C, Hkv); k/v_tail (B, R, Hkv, Dh) ring-ordered with the
    chunk rows already written; t, cov (B,) int32 → output shaped like q.
    Rows at index >= chunk_len are fully masked and must be discarded by
    the caller (their softmax is a degenerate uniform)."""
    if interpret is None:
        from repro.kernels.ops import interpret_default
        interpret = interpret_default()
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, l, hq, dh = q.shape
    c = k_cents.shape[1]
    r = k_tail.shape[1]
    hkv = k_cents.shape[2]
    g = hq // hkv
    qh = q.reshape(b, l, hkv, g, dh).transpose(0, 2, 1, 3, 4)
    cnt_t = counts.transpose(0, 2, 1)                    # (B, Hkv, C)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    cov = jnp.broadcast_to(jnp.asarray(cov, jnp.int32), (b,))
    if chunk_len is None:
        chunk_len = jnp.ones((b,), jnp.int32)
    chunk_len = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))

    out = pl.pallas_call(
        functools.partial(_kernel, l=l, g=g, r=r, scale=scale,
                          softcap=softcap),
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1,), lambda i, h: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, l, g, dh), lambda i, h: (i, h, 0, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, c), lambda i, h: (i, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, r, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, r, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, l, g, dh), lambda i, h: (i, h, 0, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, hkv, l, g, dh), q.dtype),
        interpret=interpret,
    )(t, cov, chunk_len, qh, k_cents, v_cents, cnt_t, k_tail, v_tail)
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, l, hq, dh)
    return out[:, 0] if squeeze else out
