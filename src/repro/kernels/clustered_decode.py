"""Pallas TPU kernel: fused clustered-KV decode attention.

One-token attention over [median centroids ⊕ exact tail ring] — the
clustered-attention estimator of the paper's memory manager — in a single
VMEM-resident pass per (batch, kv-head) grid instance:

  * centroid logits get the +log(count) bias (a centroid standing for m
    keys receives the softmax mass of m identical-score keys); empty
    clusters (count == 0) are masked,
  * tail logits are masked by ring validity (position in [cov, t]; the
    positions below ``cov`` are already summarized by centroids, so the
    partition is exact — nothing double-counted, nothing lost),
  * one joint softmax over the concatenated score row and two MXU
    combines against v_cents / v_tail.

Per-slot ``t`` / ``cov`` vectors come in through SMEM, so a continuous
batcher with slots at different depths runs in the same launch.

Layout (grid = (B, Hkv)):
  t, cov   (1,)  SMEM  — this slot's valid length / centroid coverage
  q        (1, 1, G, Dh)   VMEM  — this kv-head's query group
  k_cents  (1, C, 1, Dh)   VMEM     v_cents same
  counts   (1, 1, C)       VMEM  — pre-transposed (B, Hkv, C)
  k_tail   (1, R, 1, Dh)   VMEM     v_tail same (ring order)
  out      (1, 1, G, Dh)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map
except ImportError:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map

# the replication-check kwarg was renamed check_rep -> check_vma when
# shard_map moved to the top-level namespace; resolve it by signature so
# both APIs disable the check (the Pallas call has no replication rule)
import inspect as _inspect

_SHARD_MAP_NO_CHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(shard_map).parameters
    else {"check_rep": False})

NEG = -1e30


def _kernel(t_ref, cov_ref, q_ref, kc_ref, vc_ref, cnt_ref, kt_ref, vt_ref,
            o_ref, *, r: int, scale: float, softcap):
    t = t_ref[0]
    cov = cov_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, Dh)
    kc = kc_ref[0, :, 0].astype(jnp.float32)             # (C, Dh)
    vc = vc_ref[0, :, 0].astype(jnp.float32)
    cnt = cnt_ref[0, 0].astype(jnp.float32)              # (C,)
    kt = kt_ref[0, :, 0].astype(jnp.float32)             # (R, Dh)
    vt = vt_ref[0, :, 0].astype(jnp.float32)

    s_c = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_c = jnp.tanh(s_c / softcap) * softcap
    cnt_row = cnt[None, :]                               # (1, C)
    s_c = jnp.where(cnt_row > 0,
                    s_c + jnp.log(jnp.maximum(cnt_row, 1e-9)), NEG)

    s_t = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_t = jnp.tanh(s_t / softcap) * softcap
    # ring slot s holds position s while t+1 <= R, else the wrapped window
    sl = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    tp1 = t + 1
    wrapped = tp1 - r + jnp.mod(sl - tp1, r)
    pos = jnp.where(tp1 <= r, sl, wrapped)
    ok = (pos >= 0) & (pos < tp1) & (pos >= cov)
    s_t = jnp.where(ok, s_t, NEG)

    m = jnp.maximum(s_c.max(-1, keepdims=True), s_t.max(-1, keepdims=True))
    p_c = jnp.exp(s_c - m)
    p_t = jnp.exp(s_t - m)
    l = p_c.sum(-1, keepdims=True) + p_t.sum(-1, keepdims=True)
    acc = (jax.lax.dot_general(p_c, vc, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(p_t, vt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def clustered_decode_shardmap(q, k_cents, v_cents, counts, k_tail, v_tail,
                              t, cov, *, mesh, data_axes, model_axes,
                              scale: float, softcap=None,
                              interpret: bool = False):
    """Dispatch the Pallas kernel once per mesh shard.

    The kernel grid is (batch, kv-head) and every grid cell is independent,
    so a (data, model)-sharded launch is exact: each shard runs the same
    kernel on its local (B/d, Hkv/m) block — no collectives, and the
    existing interpret-mode CPU fallback applies per shard unchanged.

    ``data_axes`` / ``model_axes`` are the mesh axis tuples partitioning the
    batch / head dims (either may be None → replicated along that dim); the
    caller (kernels.ops) checks divisibility before choosing them.  t / cov
    must already be (B,) vectors so they shard with the batch.
    """
    b = q.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    cov = jnp.broadcast_to(jnp.asarray(cov, jnp.int32), (b,))
    d, m = data_axes, model_axes
    f = shard_map(
        functools.partial(clustered_decode_pallas, scale=scale,
                          softcap=softcap, interpret=interpret),
        mesh=mesh,
        in_specs=(
            P(d, m, None),        # q        (B, Hq, Dh)
            P(d, None, m, None),  # k_cents  (B, C, Hkv, Dh)
            P(d, None, m, None),  # v_cents
            P(d, None, m),        # counts   (B, C, Hkv)
            P(d, None, m, None),  # k_tail   (B, R, Hkv, Dh)
            P(d, None, m, None),  # v_tail
            P(d),                 # t        (B,)
            P(d),                 # cov      (B,)
        ),
        out_specs=P(d, m, None),
        **_SHARD_MAP_NO_CHECK,
    )
    return f(q, k_cents, v_cents, counts, k_tail, v_tail, t, cov)


def clustered_decode_pallas(q, k_cents, v_cents, counts, k_tail, v_tail,
                            t, cov, *, scale: float, softcap=None,
                            interpret: bool | None = None):
    """q (B, Hq, Dh); k/v_cents (B, C, Hkv, Dh); counts (B, C, Hkv);
    k/v_tail (B, R, Hkv, Dh) ring-ordered; t, cov (B,) int32
    → (B, Hq, Dh)."""
    if interpret is None:
        from repro.kernels.ops import interpret_default
        interpret = interpret_default()
    b, hq, dh = q.shape
    c = k_cents.shape[1]
    r = k_tail.shape[1]
    hkv = k_cents.shape[2]
    g = hq // hkv
    qh = q.reshape(b, hkv, g, dh)
    cnt_t = counts.transpose(0, 2, 1)                    # (B, Hkv, C)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    cov = jnp.broadcast_to(jnp.asarray(cov, jnp.int32), (b,))

    out = pl.pallas_call(
        functools.partial(_kernel, r=r, scale=scale, softcap=softcap),
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1,), lambda i, h: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i, h: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda i, h: (i, h, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, c, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, c), lambda i, h: (i, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, r, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, r, 1, dh), lambda i, h: (i, 0, h, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda i, h: (i, h, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(t, cov, qh, k_cents, v_cents, cnt_t, k_tail, v_tail)
    return out.reshape(b, hq, dh)
