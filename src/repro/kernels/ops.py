"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode so the
kernel bodies are validated end to end; on a TPU backend they compile via
Mosaic.  Above the VMEM point-budget the grouped median falls back to the
pure-JAX two-level reduction-tree path (``core.bitserial``) — mirroring the
paper, where datasets beyond one storage array go through the hierarchical
merge network.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitserial
from repro.kernels import bitserial_median as _bsm
from repro.kernels import clustered_decode as _cd
from repro.kernels import distance_argmin as _da

# points that fit the VMEM-resident kernel comfortably (u + active + forced
# + temporaries at TD=128 lanes ≈ 4 f32 planes ⇒ ~8 MB at 4096 points)
MAX_KERNEL_POINTS = 4096


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "bits", "d_block", "interpret",
                                   "force_kernel"))
def grouped_median_bits(u, assign, k: int, weights=None, *, bits: int = 32,
                        d_block: int = 128, interpret: bool | None = None,
                        force_kernel: bool = False):
    """Per-cluster bit-serial medians of unsigned-ordered uint32 data.

    u (N, D), assign (N,) → (med (k, D) uint32, totals (k,) f32).
    """
    n = u.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if interpret is None:
        interpret = _interpret_default()
    if n <= MAX_KERNEL_POINTS or force_kernel:
        med = _bsm.grouped_median_pallas(u, assign, weights, k, bits=bits,
                                         d_block=d_block, interpret=interpret)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        totals = (onehot * weights[:, None]).sum(axis=0)
        return med, totals
    return bitserial.grouped_median_bits(u, assign, k, weights=weights,
                                         bits=bits)


@partial(jax.jit, static_argnames=("metric", "n_block", "interpret"))
def distance_argmin(x, cents, *, metric: str = "l2", n_block: int = 1024,
                    interpret: bool | None = None):
    """Closest-centroid assignment: (assign (N,), mindist (N,))."""
    if interpret is None:
        interpret = _interpret_default()
    nb = min(n_block, max(8, x.shape[0]))
    return _da.distance_argmin_pallas(x, cents, metric=metric, n_block=nb,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def clustered_decode(q, k_cents, v_cents, counts, k_tail, v_tail, t, cov, *,
                     scale: float, softcap: float | None = None,
                     interpret: bool | None = None):
    """Fused clustered-KV decode attention (centroids ⊕ tail ring).

    q (B, Hq, Dh); k/v_cents (B, C, Hkv, Dh); counts (B, C, Hkv);
    k/v_tail (B, R, Hkv, Dh); t, cov scalar or (B,) → (B, Hq, Dh)."""
    if interpret is None:
        interpret = _interpret_default()
    return _cd.clustered_decode_pallas(
        q, k_cents, v_cents, counts, k_tail, v_tail, t, cov,
        scale=scale, softcap=softcap, interpret=interpret)
