"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode so the
kernel bodies are validated end to end; on a TPU backend they compile via
Mosaic.  Above the VMEM point-budget the grouped median falls back to the
pure-JAX two-level reduction-tree path (``core.bitserial``) — mirroring the
paper, where datasets beyond one storage array go through the hierarchical
merge network.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitserial
from repro.kernels import bitserial_median as _bsm
from repro.kernels import clustered_decode as _cd
from repro.kernels import distance_argmin as _da
from repro.kernels import paged_clustered_decode as _pcd

# points that fit the VMEM-resident kernel comfortably (u + active + forced
# + temporaries at TD=128 lanes ≈ 4 f32 planes ⇒ ~8 MB at 4096 points)
MAX_KERNEL_POINTS = 4096


def interpret_default() -> bool:
    """True when the Pallas kernels must run in interpret mode (no Mosaic
    lowering available).  Single source of truth for backend detection —
    every kernel wrapper (here and in the kernel modules) resolves
    ``interpret=None`` through this helper, so the CPU fallback can't
    drift between call sites."""
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "bits", "d_block", "interpret",
                                   "force_kernel"))
def grouped_median_bits(u, assign, k: int, weights=None, *, bits: int = 32,
                        d_block: int = 128, interpret: bool | None = None,
                        force_kernel: bool = False):
    """Per-cluster bit-serial medians of unsigned-ordered uint32 data.

    u (N, D), assign (N,) → (med (k, D) uint32, totals (k,) f32).
    """
    n = u.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if interpret is None:
        interpret = interpret_default()
    if n <= MAX_KERNEL_POINTS or force_kernel:
        med = _bsm.grouped_median_pallas(u, assign, weights, k, bits=bits,
                                         d_block=d_block, interpret=interpret)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        totals = (onehot * weights[:, None]).sum(axis=0)
        return med, totals
    return bitserial.grouped_median_bits(u, assign, k, weights=weights,
                                         bits=bits)


@partial(jax.jit, static_argnames=("metric", "n_block", "interpret"))
def distance_argmin(x, cents, *, metric: str = "l2", n_block: int = 1024,
                    interpret: bool | None = None):
    """Closest-centroid assignment: (assign (N,), mindist (N,))."""
    if interpret is None:
        interpret = interpret_default()
    nb = min(n_block, max(8, x.shape[0]))
    return _da.distance_argmin_pallas(x, cents, metric=metric, n_block=nb,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def _clustered_decode_jit(q, k_cents, v_cents, counts, k_tail, v_tail, t,
                          cov, chunk_len, *, scale: float,
                          softcap: float | None, interpret: bool):
    return _cd.clustered_decode_pallas(
        q, k_cents, v_cents, counts, k_tail, v_tail, t, cov, chunk_len,
        scale=scale, softcap=softcap, interpret=interpret)


def _kernel_shard_axes(rules, b: int, hq: int, hkv: int):
    """(data_axes, model_axes) for a (B, Hq/Hkv, …) kernel launch under the
    active sharding rules, or (None, None) when nothing divides.  Heads
    shard over the model axis only when BOTH the query and kv head counts
    divide (the GQA group must stay intact per shard)."""
    data_axes = rules.axes_for("batch", b)
    model_axes = rules.axes_for("heads", hq)
    if model_axes is not None and rules.axes_for("kv_heads", hkv) != model_axes:
        model_axes = None
    return data_axes, model_axes


def clustered_decode(q, k_cents, v_cents, counts, k_tail, v_tail, t, cov,
                     chunk_len=None, *, scale: float,
                     softcap: float | None = None,
                     interpret: bool | None = None):
    """Fused clustered-KV decode attention (centroids ⊕ tail ring).

    q (B, Hq, Dh) for plain decode, or (B, L, Hq, Dh) for the mixed-mode
    launch (chunked prefill interleaved with decode) with per-slot
    ``chunk_len`` (B,) valid query rows; k/v_cents (B, C, Hkv, Dh);
    counts (B, C, Hkv); k/v_tail (B, R, Hkv, Dh); t, cov scalar or (B,)
    → output shaped like q.

    When a sharding-rules context is active (mesh serving), the Pallas
    kernel is dispatched per (data, model) mesh shard via shard_map —
    slots partition over ``data``, kv-head grid cells over ``model`` —
    with divisibility-aware fallback to replication.  Dispatch happens at
    trace time, so this wrapper is deliberately un-jitted (a cached trace
    must never leak across rules contexts); the plain path keeps its own
    jit below."""
    if interpret is None:
        interpret = interpret_default()
    b = q.shape[0]
    if chunk_len is None:
        chunk_len = jnp.ones((b,), jnp.int32)
    chunk_len = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    hq = q.shape[-2]
    from repro.sharding import current_rules
    r = current_rules()
    if r is not None:
        data_axes, model_axes = _kernel_shard_axes(
            r, b, hq, k_cents.shape[2])
        if data_axes is not None or model_axes is not None:
            return _cd.clustered_decode_shardmap(
                q, k_cents, v_cents, counts, k_tail, v_tail, t, cov,
                chunk_len, mesh=r.mesh, data_axes=data_axes,
                model_axes=model_axes, scale=scale, softcap=softcap,
                interpret=interpret)
    return _clustered_decode_jit(
        q, k_cents, v_cents, counts, k_tail, v_tail, t, cov, chunk_len,
        scale=scale, softcap=softcap, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def _paged_clustered_decode_jit(q, k_cents, v_cents, counts, k_pool, v_pool,
                                row_slot, row_bt, qpos1, tw, cov, wlo, *,
                                scale: float, softcap: float | None,
                                interpret: bool):
    return _pcd.paged_clustered_decode_pallas(
        q, k_cents, v_cents, counts, k_pool, v_pool, row_slot, row_bt,
        qpos1, tw, cov, wlo, scale=scale, softcap=softcap,
        interpret=interpret)


def paged_clustered_decode(q, k_cents, v_cents, counts, k_pool, v_pool,
                           row_slot, row_bt, qpos1, tw, cov, row_wlo=None,
                           *, scale: float,
                           softcap: float | None = None,
                           interpret: bool | None = None):
    """Paged clustered-KV decode over packed ragged rows.

    The paged-vs-dense choice is made at trace time by the caller
    (models/attention dispatches here when the cache carries a block
    pool, and to ``clustered_decode`` above for the dense per-slot ring)
    — this wrapper then picks shard_map vs plain launch exactly like the
    dense one.  q (N, Hq, Dh) packed (slot, position) rows; k/v_pool
    (nb, bs, Hkv, Dh) tail block pools; row_bt (N, T) physical block per
    ring block (all entries valid — unmapped blocks pre-sanitized to a
    masked garbage block); qpos1/tw/cov per-row position + 1 / ring
    watermark / coverage frontier; ``row_wlo`` (N,) per-row retention
    window lower bound (None ⇒ zeros: frontier-only masking, the
    bit-identical pre-policy behavior).

    Under mesh serving rows, slots, and the pool shard over ``data``
    (block ids are global and rebased per shard inside the island), heads
    over ``model``.  Divisibility of the rows, slots, AND pool blocks is
    required for data sharding — the engine packs rows per shard, so a
    fallback to replication only triggers for indivisible slot counts,
    matching the dense path."""
    if interpret is None:
        interpret = interpret_default()
    if row_wlo is None:
        row_wlo = jnp.zeros_like(jnp.asarray(qpos1, jnp.int32))
    hq = q.shape[-2]
    from repro.sharding import current_rules
    r = current_rules()
    if r is not None:
        data_axes, model_axes = _kernel_shard_axes(
            r, k_cents.shape[0], hq, k_cents.shape[2])
        if data_axes is not None:
            # rows and pool must split the same way as slots
            total = 1
            for a in data_axes:
                total *= r.mesh.shape[a]
            if q.shape[0] % total or k_pool.shape[0] % total:
                data_axes = None
        if data_axes is not None or model_axes is not None:
            return _pcd.paged_clustered_decode_shardmap(
                q, k_cents, v_cents, counts, k_pool, v_pool, row_slot,
                row_bt, qpos1, tw, cov, row_wlo, mesh=r.mesh,
                data_axes=data_axes, model_axes=model_axes, scale=scale,
                softcap=softcap, interpret=interpret)
    return _paged_clustered_decode_jit(
        q, k_cents, v_cents, counts, k_pool, v_pool, row_slot, row_bt,
        qpos1, tw, cov, row_wlo, scale=scale, softcap=softcap,
        interpret=interpret)
