"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    """Auto-typed mesh across jax versions: ``axis_types`` (and AxisType
    itself) only exist on newer jax; older versions are Auto-only."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(at.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return _mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """'dxm' (e.g. '2x4') → (data, model) ints."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"mesh spec must be 'DATAxMODEL' (e.g. 2x4), "
                         f"got {spec!r}")
    data, model = (int(p) for p in parts)
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return data, model


def make_serving_mesh(spec: str):
    """(data, model) mesh for the serving engine from a CLI 'dxm' spec.

    Decode slots shard over ``data``, attention heads over ``model``
    (runtime/server.py).  On a CPU host, fake devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before the
    first jax use — the error message reminds the caller.
    """
    data, model = parse_mesh_spec(spec)
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"mesh {spec} needs {data * model} devices but only {n} are "
            f"visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model} "
            f"before jax initializes")
    return _mesh((data, model), ("data", "model"))
