"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
