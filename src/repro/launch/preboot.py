"""Pre-jax-boot helpers for CLI entry points.

XLA only honors ``--xla_force_host_platform_device_count`` when XLA_FLAGS
is set before the backend initializes, so CLIs that accept ``--mesh dxm``
call this on raw argv before their first jax use.  Deliberately jax-free
(and lenient: malformed specs are left for ``launch.mesh.parse_mesh_spec``
to reject with a proper error once jax is up).
"""

from __future__ import annotations

import os


def force_host_devices_for_mesh(argv) -> None:
    """Peek at ``--mesh dxm`` / ``--mesh=dxm`` in ``argv`` and force enough
    fake host devices for it, unless XLA_FLAGS already pins a count."""
    spec = None
    for i, a in enumerate(argv):
        if a.startswith("--mesh="):
            spec = a.split("=", 1)[1]
        elif a == "--mesh" and i + 1 < len(argv):
            spec = argv[i + 1]
    if not spec:
        return
    try:
        need = 1
        for p in spec.lower().split("x"):
            need *= int(p)
    except ValueError:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if need > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={need}".strip())
