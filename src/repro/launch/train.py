"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 200 --batch 8 --seq 128

On this CPU container use ``--reduced`` (family-preserving small config);
on a real fleet the same entry point drives the full config on the
production mesh (--mesh pod|multipod).  Checkpoint/restart, straggler
logging, and optional cross-pod gradient compression are wired through.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import grad_compress
from repro.data import pipeline
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.models.config import ShapeCell
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.sharding import (Rules, default_table, tree_param_specs, use_rules)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--grad-compress", action="store_true",
                    help="k-means codebook gradient compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    cell = ShapeCell("cli", args.seq, args.batch, "train")
    opts = steps_mod.pick_options(cfg, mesh, cell, remat=True)
    aw = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                           total_steps=args.steps)

    gt = None
    if args.grad_compress:
        gt = grad_compress.make_grad_transform(grad_compress.CompressConfig())

    rules = Rules(mesh, default_table("pod" in mesh.axis_names))
    raw_step = steps_mod.make_train_step(cfg, aw, opts, grad_transform=gt)

    def step_fn(params, opt_state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        with use_rules(rules):
            return jax.jit(raw_step)(params, opt_state, b)

    data = pipeline.SyntheticLM(cfg, pipeline.DataConfig(
        seed=args.seed, global_batch=args.batch, seq_len=args.seq))
    tcfg = TrainerConfig(n_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, log_every=10)
    trainer = Trainer(cfg, tcfg, aw, step_fn, data,
                      init_params_fn=lambda: tfm.init_params(
                          jax.random.PRNGKey(args.seed), cfg))
    trainer.run()
    print(f"[train] done: final loss {trainer.losses[-1]:.4f}, "
          f"stragglers flagged: {len(trainer.stragglers)}")


if __name__ == "__main__":
    main()
