import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines — before ANY other import (jax locks the
# device count at first init).  Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.roofline.hlo_parse import analyze_hlo  # noqa: E402

# long_500k requires sub-quadratic serving; pure full-attention archs are
# skipped per the brief (documented in DESIGN.md §7)
LONG_OK = {"mamba2-2.7b", "recurrentgemma-9b", "gemma3-4b", "gemma2-27b"}


def cell_is_skipped(arch: str, shape_name: str):
    if shape_name == "long_500k" and arch not in LONG_OK:
        return ("pure full-attention arch: 500k-token decode is out of its "
                "design envelope (no sliding-window/SSM path)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None, moe_impl: str = None) -> dict:
    cfg = configs.get_config(arch)
    if moe_impl and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl=moe_impl))
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, info = steps.lower_step(cfg, mesh, cell,
                                     opts=None if not overrides else
                                     steps.pick_options(cfg, mesh, cell,
                                                        **overrides))
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_stats = analyze_hlo(compiled.as_text())
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "info": info,
        "trace_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "hlo_stats": hlo_stats,
    }
    print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
          f"compile {result['compile_s']}s, "
          f"per-device flops {hlo_stats['flops']:.3e}, "
          f"hbm {hlo_stats['hbm_bytes']:.3e} B, "
          f"collectives {hlo_stats['collectives']}")
    print(f"[dryrun] memory_analysis: {mem}")      # proves it fits
    print(f"[dryrun] cost_analysis: flops={cost.get('flops')} "
          f"bytes={cost.get('bytes accessed')}")   # FLOPs/bytes for §Roofline
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod",
                                                       "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "global", "sharded", "a2a"])
    ap.add_argument("--kv-mode", default=None,
                    choices=[None, "exact", "clustered", "int8"])
    args = ap.parse_args()

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ({"pod": [False], "multipod": [True],
               "both": [False, True]})[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] cached: {tag}")
                    continue
                skip = cell_is_skipped(arch, shape)
                if skip:
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "skipped": skip}
                    print(f"[dryrun] SKIP {tag}: {skip}")
                else:
                    try:
                        ov = ({"kv_mode": args.kv_mode}
                              if args.kv_mode else None)
                        res = run_cell(arch, shape, mp,
                                       overrides=ov,
                                       moe_impl=args.moe_impl)
                    except Exception as e:  # noqa: BLE001
                        traceback.print_exc()
                        failures.append(tag)
                        res = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "error": f"{type(e).__name__}: {e}"}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("[dryrun] FAILURES:", failures)
        raise SystemExit(1)
    print("[dryrun] all requested cells done")


if __name__ == "__main__":
    main()
