"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 24 --batch-size 4

Drives the full request-processing path: request queue → bit-serial
k-medians batcher → prefill → decode loop; reports padding waste
(clustered vs FIFO) and throughput.  On a real fleet the same entry point
serves the full config on the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.request_cluster import Request, plan_batches, plan_fifo
from repro.models import transformer as tfm
from repro.runtime.server import Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--no-clustering", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if cfg.is_encdec or cfg.attention_free:
        print(f"[serve] note: {args.arch} decode path exercised via its "
              f"own cache family")
    rng = np.random.default_rng(args.seed)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)

    lens = np.where(rng.random(args.requests) < 0.5,
                    rng.integers(8, 24, args.requests),
                    rng.integers(64, min(160, args.max_seq - args.max_new),
                                 args.requests))
    reqs = [Request(i, int(l), args.max_new) for i, l in enumerate(lens)]
    prompts = {r.uid: rng.integers(0, cfg.vocab, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}

    fifo = plan_fifo(reqs, args.batch_size)
    clus = plan_batches(reqs, args.batch_size)
    print(f"[serve] padding waste: fifo {fifo.waste * 100:.1f}% → "
          f"clustered {clus.waste * 100:.1f}%")

    srv = Server(cfg, ServerConfig(
        batch_size=args.batch_size, max_seq=args.max_seq,
        use_clustered_batching=not args.no_clustering), params)
    t0 = time.perf_counter()
    outs = srv.serve(reqs, prompts)
    dt = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs)
    print(f"[serve] {len(outs)} completions, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s), mean decode "
          f"{np.mean([o.decode_ms for o in outs]):.1f} ms/req")


if __name__ == "__main__":
    main()
