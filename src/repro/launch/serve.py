"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 24 --batch-size 4

Mesh-sharded (slots × tensor parallel), e.g. on an 8-device host:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 24 --batch-size 8 --mesh 2x4

Sliding-window models (gemma2/3-style 'L' layers) serve chunked + paged
through the retention-policy layer — ``--config`` is an alias for
``--arch`` that reads naturally when picking one:

    PYTHONPATH=src python -m repro.launch.serve --config gemma2-27b \
        --reduced --requests 24 --prefill-chunk 16 --paged --kv-clusters 8

Drives the full request-processing path: request queue → bit-serial
k-medians batcher → prefill → decode loop; reports padding waste
(clustered vs FIFO) and throughput.  ``--mesh DATAxMODEL`` runs the
continuous batcher sharded over a (data, model) device mesh — decode
slots and their clustered KV caches over ``data``, attention heads over
``model``.  On a real fleet the same entry point serves the full config
on the production mesh; on CPU the needed fake devices are forced via
XLA_FLAGS before jax initializes (handled below).
"""

from __future__ import annotations

import sys

from repro.launch.preboot import force_host_devices_for_mesh

force_host_devices_for_mesh(sys.argv)

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.request_cluster import (Request, plan_batches,  # noqa: E402
                                        plan_fifo)
from repro.core import kv_compress  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models import transformer as tfm  # noqa: E402
from repro.runtime.kv_pool import PagedKVConfig  # noqa: E402
from repro.runtime.prefix_cache import PrefixShareConfig  # noqa: E402
from repro.runtime.scheduler import SLOConfig  # noqa: E402
from repro.runtime.server import Server, ServerConfig  # noqa: E402
from repro.runtime.telemetry import (TelemetryConfig,  # noqa: E402
                                     phase_breakdown)
from repro.runtime.template_store import TemplateStoreConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--config", dest="arch", required=True,
                    choices=list(configs.ARCH_IDS),
                    help="model config to serve; windowed configs "
                         "(gemma2-27b, gemma3-4b) run their 'L' layers "
                         "under WindowRetention")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--no-clustering", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serving mesh, e.g. 2x4 (slots shard "
                         "over data, heads over model)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill interleaved with decode: feed "
                         "admission prompts in chunks of this many tokens "
                         "fused into the decode launch (0 = blocking "
                         "prefill); hides admission latency under load")
    ap.add_argument("--paged", action="store_true",
                    help="paged clustered-KV memory manager: tail rings "
                         "live in a per-shard block pool behind per-slot "
                         "block tables, decode runs as packed ragged "
                         "launches (compute ∝ real tokens); implies "
                         "clustered-KV serving (--kv-clusters et al.)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="prefix-sharing paged admission: prompts "
                         "sharing a prefix adopt the same tail-ring "
                         "blocks (copy-on-write) and reuse absorbed "
                         "prompt centroids instead of re-prefilling; "
                         "requires --paged and --prefill-chunk")
    ap.add_argument("--persist-templates", action="store_true",
                    help="persistent cross-serve template store "
                         "(subsumes --prefix-share): registered prefix "
                         "boundaries and their pinned pool blocks "
                         "survive between serve() calls, and request "
                         "traffic is clustered online onto template "
                         "medoids; the demo serves the queue twice to "
                         "show the warm second serve (size the pool "
                         "with --pool-blocks headroom or pressure "
                         "evicts every entry before the drain)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: ring positions per pool block (must "
                         "divide --keep-recent)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged: blocks per data shard (0 = full "
                         "provisioning; less oversubscribes and relies "
                         "on compaction give-back)")
    ap.add_argument("--kv-clusters", type=int, default=None,
                    help="clustered serving: centroids per slot/head "
                         "(setting any --kv-* flag enables clustered-KV "
                         "serving; default 32)")
    ap.add_argument("--keep-recent", type=int, default=None,
                    help="clustered serving: exact tail ring length "
                         "(default 64)")
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="clustered serving: decode steps between "
                         "compactions (default 32)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a request-lifecycle Chrome trace-event "
                         "JSON here (load in Perfetto / chrome://tracing: "
                         "one process per data shard, one thread per "
                         "decode slot) and print the engine-step phase "
                         "breakdown; tracing is host-side only and "
                         "leaves tokens bit-identical")
    ap.add_argument("--priority-demo", action="store_true",
                    help="SLO scheduling demo (requires --paged): mark "
                         "the last quarter of the queue priority-1, "
                         "shrink the pool below full provisioning, and "
                         "serve under the brownout ladder (defer -> "
                         "preempt/swap -> shed); prints per-class TTFT "
                         "and the sched_* counters")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if cfg.is_encdec or cfg.attention_free:
        print(f"[serve] note: {args.arch} decode path exercised via its "
              f"own cache family")
    if args.prefill_chunk or args.paged:
        report = cfg.serving_gate_report()
        if report is not None:
            ap.error(f"{args.arch} cannot serve chunked/paged: {report}")
    if cfg.sliding_window and "L" in cfg.layer_pattern:
        n_local = sum(cfg.pattern_for_layer(i) == "L"
                      for i in range(cfg.n_layers))
        print(f"[serve] windowed model: {n_local}/{cfg.n_layers} local "
              f"layers under WindowRetention(window="
              f"{cfg.sliding_window}); global layers retire at the "
              f"cov frontier")
    rng = np.random.default_rng(args.seed)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)

    lens = np.where(rng.random(args.requests) < 0.5,
                    rng.integers(8, 24, args.requests),
                    rng.integers(64, min(160, args.max_seq - args.max_new),
                                 args.requests))
    reqs = [Request(i, int(l), args.max_new) for i, l in enumerate(lens)]
    if args.priority_demo:
        if not args.paged:
            ap.error("--priority-demo needs the paged clustered engine "
                     "(add --paged)")
        if any(cfg.pattern_for_layer(i) != "G" for i in range(cfg.n_layers)):
            ap.error(f"--priority-demo: {args.arch} has windowed layers; "
                     f"the SLO scheduler serves all-global clustered "
                     f"models only")
        # protected class arrives LAST — the worst case for FIFO, and
        # exactly what priority preemption exists to fix
        n_high = max(len(reqs) // 4, 1)
        reqs = [Request(r.uid, r.prompt_len, r.max_new_tokens,
                        priority=1 if r.uid >= len(reqs) - n_high else 0)
                for r in reqs]
        print(f"[serve] priority demo: {n_high}/{len(reqs)} requests "
              f"priority-1 at the queue tail")
    prompts = {r.uid: rng.integers(0, cfg.vocab, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    if args.persist_templates:
        # a template store needs template traffic: all-distinct random
        # prompts register boundaries that never recur, so they churn
        # through the entry cap without ever earning a hit.  Give the
        # long half of the queue a shared 64-token template — its
        # boundary entries collect hits in the first serve, and the
        # hits x tokens-reused eviction score then protects them from
        # the one-off boundaries the short prompts keep registering.
        tpl = rng.integers(0, cfg.vocab, size=(64,)).astype(np.int32)
        tpl_n = sum(1 for r in reqs if r.prompt_len >= 64)
        for r in reqs:
            if r.prompt_len >= 64:
                prompts[r.uid][:64] = tpl
        print(f"[serve] template traffic: {tpl_n}/{len(reqs)} prompts "
              f"share a 64-token template prefix")

    fifo = plan_fifo(reqs, args.batch_size)
    clus = plan_batches(reqs, args.batch_size)
    print(f"[serve] padding waste: fifo {fifo.waste * 100:.1f}% → "
          f"clustered {clus.waste * 100:.1f}%")

    mesh = None
    if args.mesh:
        mesh = make_serving_mesh(args.mesh)
        print(f"[serve] mesh {args.mesh}: slots over data={mesh.shape['data']}"
              f", heads over model={mesh.shape['model']}")
    ccfg = paged = None
    clustered = args.paged or any(
        v is not None for v in (args.kv_clusters, args.keep_recent,
                                args.refresh_every))
    if clustered:
        ccfg = kv_compress.KVCompressConfig(
            n_clusters=args.kv_clusters or 32, iters=4,
            keep_recent=args.keep_recent or 64,
            refresh_every=args.refresh_every or 32)
        print(f"[serve] clustered KV: C={ccfg.n_clusters} "
              f"R={ccfg.keep_recent} refresh={ccfg.refresh_every}")
    if args.paged:
        pool_blocks = args.pool_blocks
        if args.persist_templates and not pool_blocks:
            # the store pins entry blocks BETWEEN serves, so "full
            # provisioning" (the 0 default: exactly the live rings)
            # leaves no room for them — pool pressure would reclaim
            # every warm entry before the second serve could adopt it.
            # Double the ring footprint so pins live in the surplus.
            shards = mesh.shape["data"] if mesh is not None else 1
            per_slot = (ccfg.keep_recent + args.block_size - 1) \
                // args.block_size
            pool_blocks = 2 * max(args.batch_size // shards, 1) * per_slot
        if args.priority_demo and not pool_blocks:
            # undersubscribe on purpose: the scheduler only has work to
            # do when the pool can't hold every slot's tail ring at once
            shards = mesh.shape["data"] if mesh is not None else 1
            per_slot = (ccfg.keep_recent + args.block_size - 1) \
                // args.block_size
            slots = max(args.batch_size // shards, 1)
            pool_blocks = max(per_slot + 1, (3 * slots * per_slot) // 4)
        paged = PagedKVConfig(block_size=args.block_size,
                              pool_blocks=pool_blocks)
        print(f"[serve] paged KV: {args.block_size}-position blocks, "
              f"{pool_blocks or 'auto'} blocks/shard"
              + (" (auto-doubled for template-store headroom)"
                 if args.persist_templates
                 and pool_blocks != args.pool_blocks else "")
              + (" (auto-tightened to force brownout pressure)"
                 if args.priority_demo
                 and pool_blocks != args.pool_blocks else ""))
    pshare = tstore = None
    if args.persist_templates:
        # cap entries near the pool headroom: every entry pins blocks,
        # and a store allowed to pin more than the surplus above the
        # live rings just churns under pool pressure (0 warm hits)
        tstore = TemplateStoreConfig(max_entries=2 * args.batch_size)
        print("[serve] template store: persistent cross-serve prefix "
              "boundaries + online traffic clustering"
              + (" (subsumes --prefix-share)" if args.prefix_share
                 else ""))
    elif args.prefix_share:
        pshare = PrefixShareConfig()
        print("[serve] prefix sharing: block-granular prompt-prefix "
              "admission (copy-on-write)")
    srv = Server(cfg, ServerConfig(
        batch_size=args.batch_size, max_seq=args.max_seq,
        use_clustered_batching=not args.no_clustering, mesh=mesh,
        prefill_chunk=args.prefill_chunk, kv_compress=ccfg,
        paged=paged, prefix_share=pshare, template_store=tstore,
        scheduler=SLOConfig() if args.priority_demo else None,
        telemetry=(TelemetryConfig(trace=True) if args.trace_out
                   else None)), params)
    t0 = time.perf_counter()
    outs = srv.serve(reqs, prompts)
    dt = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs)
    print(f"[serve] {len(outs)} completions, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s), mean decode "
          f"{np.mean([o.decode_ms for o in outs]):.1f} ms/req")
    st = srv.last_stats
    if "ttft_p95_ms" in st:
        mode = (f"chunked prefill ({args.prefill_chunk}-token chunks, "
                f"{st['prefill_chunks']:.0f} chunks)"
                if args.prefill_chunk else "blocking prefill")
        print(f"[serve] {mode}: TTFT p50/p95 {st['ttft_p50_ms']:.0f}/"
              f"{st['ttft_p95_ms']:.0f} ms, ITL p50/p95 "
              f"{st['itl_p50_ms']:.1f}/{st['itl_p95_ms']:.1f} ms")
        print(f"[serve] bucketed launches: mean bucket "
              f"{st['launch_bucket_mean']:.2f} slots/shard, launched "
              f"{st['launch_rows_frac'] * 100:.0f}% of {args.batch_size} "
              f"slots per step")
    if "pool_occupancy_peak" in st and args.paged:
        print(f"[serve] paged pool: peak occupancy "
              f"{st['pool_occupancy_peak'] * 100:.0f}%, "
              f"{st['pool_allocs']:.0f} allocs / {st['pool_frees']:.0f} "
              f"frees, launch padding {st['launch_pad_frac'] * 100:.0f}%, "
              f"peak KV {st['kv_bytes_peak_per_shard'] / 1024:.0f} "
              f"KiB/shard (frag {st['kv_frag'] * 100:.0f}%)")
    retired = {k: st[k] for k in ("kv_retired_frontier", "kv_retired_window",
                                  "kv_retired_quota")
               if st.get(k)}
    if retired:
        print("[serve] retention: " + ", ".join(
            f"{k.removeprefix('kv_retired_')} retired {v:.0f} positions"
            for k, v in retired.items()))
    if ((args.prefix_share or args.persist_templates)
            and "prefix_hits" in st):
        print(f"[serve] prefix sharing: {st['prefix_hits']:.0f} hits, "
              f"{st['prefix_tokens_reused']:.0f} prompt tokens reused, "
              f"{st['kv_bytes_saved'] / 1024:.1f} KiB tail KV shared "
              f"({st['pool_cow']:.0f} copy-on-write swaps)")
    if args.priority_demo:
        prio = {r.uid: r.priority for r in reqs}
        shed = [o.uid for o in outs if o.shed]

        def p95(cls):
            vals = [o.prefill_ms for o in outs
                    if prio[o.uid] == cls and not o.shed]
            return float(np.percentile(vals, 95)) if vals else float("nan")

        print(f"[serve] SLO scheduling: TTFT p95 priority-1 "
              f"{p95(1):.0f} ms vs best-effort {p95(0):.0f} ms; "
              f"{st['sched_preemptions']:.0f} preemptions, "
              f"{st['sched_swaps_in']:.0f} swap-ins "
              f"({st['sched_reuploaded_blocks']:.0f} blocks re-uploaded, "
              f"{st['sched_readopted_blocks']:.0f} re-adopted), "
              f"{st['sched_deferrals']:.0f} deferrals, "
              f"{st['sched_sheds']:.0f} shed {shed}")
    if mesh is not None:
        if "n_data_shards" in srv.last_stats:
            ws = [f"{srv.last_stats[f'slot_waste_shard{s}']:.2f}"
                  for s in range(int(srv.last_stats['n_data_shards']))]
            print(f"[serve] per-data-shard slot waste: {' '.join(ws)}")
        elif mesh.shape["data"] > 1:
            print(f"[serve] note: batch size {args.batch_size} does not "
                  f"divide the data axis — slots replicated (no slot "
                  f"sharding); pick a batch size divisible by "
                  f"{mesh.shape['data']}")

    if args.trace_out:
        srv.export_trace(args.trace_out)
        ph = phase_breakdown(srv.last_trace)
        print(f"[serve] trace: {len(srv.last_trace)} events → "
              f"{args.trace_out} (Perfetto-loadable)")
        if ph:
            print("[serve] phase breakdown: " + ", ".join(
                f"{k.removeprefix('phase_').removesuffix('_ms')} "
                f"{v:.1f} ms" for k, v in ph.items()))

    if args.persist_templates:
        # repeat-serve demo: the store survived the drain, so re-serving
        # the same queue adopts every registered boundary from token 0
        ttft_cold = st.get("ttft_p95_ms", 0.0)
        t0 = time.perf_counter()
        outs2 = srv.serve(reqs, prompts)
        dt2 = time.perf_counter() - t0
        st2 = srv.last_stats
        same = ({o.uid: o.tokens for o in outs}
                == {o.uid: o.tokens for o in outs2})
        print(f"[serve] warm re-serve: "
              f"{sum(len(o.tokens) for o in outs2)} tokens in {dt2:.1f}s, "
              f"TTFT p95 {st2.get('ttft_p95_ms', 0.0):.0f} ms "
              f"(cold {ttft_cold:.0f} ms), "
              f"{st2.get('prefix_hits', 0.0):.0f} store hits, "
              f"tokens identical: {same}")
        print(f"[serve] template store: "
              f"{st2.get('template_entries', 0.0):.0f} entries pinning "
              f"{st2.get('template_pinned_blocks', 0.0):.0f} blocks "
              f"({st2.get('template_bytes_pinned', 0.0) / 1024:.1f} KiB), "
              f"{st2.get('template_clusters', 0.0):.0f} traffic clusters, "
              f"cohesion {st2.get('template_cohesion_mean', 0.0):.2f}")
        srv.invalidate_templates()
        print("[serve] invalidate_templates(): store dropped, pool "
              "drained to zero")


if __name__ == "__main__":
    main()
