"""Step builders + abstract input specs for every (arch × shape) cell.

``lower_step`` produces the pjit-lowered artifact for a cell on a mesh:
  * parameter/optimizer trees are abstract (jax.eval_shape — no allocation),
  * partition specs come from the name-based rules (sharding/rules.py),
  * the logical-axis rules context is active during tracing so model-level
    ``annotate`` calls resolve against the target mesh,
  * decode cells shard the KV sequence axis when the batch cannot cover the
    data axis (sequence-parallel long-context decode).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeCell
from repro.optim import adamw
from repro.sharding import (Rules, default_table, tree_param_specs, use_rules)


@dataclasses.dataclass(frozen=True)
class StepOptions:
    kv_repeat: int = 1
    fsdp: bool = False
    seq_shard: bool = False
    remat: bool = True
    loss_chunk: int = 256
    microbatch: int = 1
    kv_mode: str = "exact"        # "clustered" = paper's KV memory manager;
                                  # "int8" = quantized exact cache
    kv_clusters: int = 512
    kv_tail: int = 256
    mla_seq_shard: bool = False   # shard the MLA latent cache's seq axis
                                  # over the model axis (headless cache)


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(partial(tfm.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_prefix, n_rep, tail = tfm.layout(cfg)
    n_moe_layers = n_rep * len(cfg.layer_pattern) + len(tail)
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = n_moe_layers * (m.n_routed - m.top_k) * per_expert
    return total - inactive


def pick_kv_repeat(cfg: ModelConfig, mesh: Mesh) -> int:
    if cfg.attn_kind == "mla" or cfg.attention_free:
        return 1
    ms = mesh.shape["model"]
    kv = cfg.n_kv_heads
    if kv <= 1 or kv >= ms:
        return 1  # MQA stays un-replicated (cache size), big kv already fine
    r = ms // kv
    if kv * r == ms and cfg.n_heads % (kv * r) == 0:
        return r
    return 1


def pick_microbatch(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell,
                    budget_bytes: float = 9e9) -> int:
    """Smallest power-of-two microbatch count keeping the per-device
    activation estimate under budget.  Activation model: scan saves the
    layer-boundary hidden per layer (remat recomputes the interior), plus
    the fp32 logits chunk of the chunked CE."""
    if cell.step != "train":
        return 1
    data_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    local_b = max(cell.global_batch // data_size, 1)
    s = cell.seq_len
    m = 1
    while m < local_b:
        b_eff = local_b / m
        acts = cfg.n_layers * b_eff * s * cfg.d_model * 2 * 2.5
        logits = b_eff * 256 * cfg.padded_vocab * 4 * 2
        if acts + logits < budget_bytes:
            break
        m *= 2
    return m


def pick_options(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell,
                 **overrides) -> StepOptions:
    data_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    opts = StepOptions(
        kv_repeat=pick_kv_repeat(cfg, mesh),
        # ZeRO/FSDP pays off for optimizer+master state; serving steps keep
        # weights TP-resident (re-gathering them per token is pure waste)
        fsdp=param_count(cfg) > 2e10 and cell.step == "train",
        seq_shard=(cell.step == "decode"
                   and cell.global_batch < data_size),
        mla_seq_shard=(cfg.attn_kind == "mla" and cell.step == "decode"),
        microbatch=pick_microbatch(cfg, mesh, cell),
    )
    return dataclasses.replace(opts, **overrides)


# ---------------------------------------------------------------------------
# Input specs (abstract) + partition specs
# ---------------------------------------------------------------------------


def batch_struct(cfg: ModelConfig, cell: ShapeCell):
    """ShapeDtypeStructs for the step inputs (weak-type-correct stand-ins)."""
    gb, s = cell.global_batch, cell.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if cell.step == "train":
        out = {}
        if cfg.is_encdec:
            se = s // 2
            out["enc_embeds"] = jax.ShapeDtypeStruct((gb, se, cfg.d_model),
                                                     bf16)
            out["tokens"] = jax.ShapeDtypeStruct((gb, s - se), i32)
            out["labels"] = jax.ShapeDtypeStruct((gb, s - se), i32)
        else:
            st = s - cfg.n_frontend_tokens
            if cfg.n_frontend_tokens:
                out["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (gb, cfg.n_frontend_tokens, cfg.d_model), bf16)
            out["tokens"] = jax.ShapeDtypeStruct((gb, st), i32)
            out["labels"] = jax.ShapeDtypeStruct((gb, st), i32)
        return out
    if cell.step == "prefill":
        out = {}
        if cfg.is_encdec:
            se = s // 2
            out["enc_embeds"] = jax.ShapeDtypeStruct((gb, se, cfg.d_model),
                                                     bf16)
            out["tokens"] = jax.ShapeDtypeStruct((gb, s - se), i32)
        else:
            st = s - cfg.n_frontend_tokens
            if cfg.n_frontend_tokens:
                out["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (gb, cfg.n_frontend_tokens, cfg.d_model), bf16)
            out["tokens"] = jax.ShapeDtypeStruct((gb, st), i32)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((gb, 1), i32),
            "t": jax.ShapeDtypeStruct((), i32)}


def batch_pspec(cfg: ModelConfig, cell: ShapeCell, rules: Rules):
    b = rules.axes_for("batch", cell.global_batch)
    if cell.step in ("train", "prefill"):
        spec = {"tokens": P(b, None)}
        if cell.step == "train":
            spec["labels"] = P(b, None)
        if cfg.is_encdec:
            spec["enc_embeds"] = P(b, None, None)
        elif cfg.n_frontend_tokens:
            spec["frontend_embeds"] = P(b, None, None)
        return spec
    return {"tokens": P(b, None), "t": P()}


def cache_struct(cfg: ModelConfig, cell: ShapeCell, opts: StepOptions):
    def build():
        if cfg.is_encdec:
            se = cell.seq_len // 2
            enc = jnp.zeros((cell.global_batch, se, cfg.d_model),
                            jnp.bfloat16)
            _, cache = tfm.prefill(
                tfm.init_params(jax.random.PRNGKey(0), cfg), cfg,
                jnp.zeros((cell.global_batch, se), jnp.int32),
                max_seq=se, enc_embeds=enc, kv_repeat=opts.kv_repeat)
            return cache
        return tfm.init_cache(cfg, cell.global_batch, cell.seq_len,
                              opts.kv_repeat, kv_mode=opts.kv_mode,
                              kv_clusters=opts.kv_clusters,
                              kv_tail=opts.kv_tail)

    return jax.eval_shape(build)


def _cache_leaf_spec(path: str, shape, rules: Rules) -> P:
    b = rules.axes_for("batch", shape[0]) if len(shape) else None
    if path.endswith("_scale"):
        return P(rules.axes_for("kv_heads", shape[0]))
    if path.endswith("/k") or path.endswith("/v"):
        return P(b, rules.axes_for("kvseq", shape[1]),
                 rules.axes_for("kv_heads", shape[2]), None)
    if path.endswith("ckv") or path.endswith("kpe"):
        return P(b, rules.axes_for("kvseq", shape[1]), None)
    if path.endswith("conv"):
        return P(b, None, rules.axes_for("ssm_ch", shape[2]))
    if path.endswith("ssm"):
        return P(b, rules.axes_for("ssm_heads", shape[1]), None, None)
    if path.endswith("/h"):
        return P(b, rules.axes_for("lru", shape[1]))
    return P(*([b] + [None] * (len(shape) - 1))) if len(shape) else P()


def cache_pspecs(cache_shapes, rules: Rules):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key(k) for k in kp)
        # scan-stacked caches carry a leading layer dim
        shape = leaf.shape
        if "scan" in path and len(shape) >= 1:
            inner = _cache_leaf_spec(path, shape[1:], rules)
            specs.append(P(*([None] + list(inner))))
        else:
            specs.append(_cache_leaf_spec(path, shape, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, aw: adamw.AdamWConfig,
                    opts: StepOptions, grad_transform=None):
    def loss_fn(params, batch):
        return tfm.train_loss(params, cfg, batch, kv_repeat=opts.kv_repeat,
                              remat=opts.remat, loss_chunk=opts.loss_chunk)

    def step(params, opt_state, batch):
        if opts.microbatch > 1:
            grads, (loss, metrics) = _accum_grads(loss_fn, params, batch,
                                                  opts.microbatch)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(grads, opt_state, params, aw,
                                             grad_transform=grad_transform)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return step


def _accum_grads(loss_fn, params, batch, n_micro: int):
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        gsum, lsum = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        gsum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
        return (gsum, lsum + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), ms = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    metrics = jax.tree.map(lambda m: m[-1], ms)
    return grads, (lsum / n_micro, metrics)


def make_prefill_step(cfg: ModelConfig, cell: ShapeCell, opts: StepOptions):
    def step(params, batch):
        return tfm.prefill(
            params, cfg, batch["tokens"],
            max_seq=(cell.seq_len // 2 if cfg.is_encdec else cell.seq_len),
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            kv_repeat=opts.kv_repeat)

    return step


def make_decode_step(cfg: ModelConfig, opts: StepOptions):
    def step(params, cache, batch):
        return tfm.decode_step(params, cfg, cache, batch["tokens"],
                               batch["t"], kv_repeat=opts.kv_repeat)

    return step


# ---------------------------------------------------------------------------
# Lowering driver (the dry-run entry)
# ---------------------------------------------------------------------------


def lower_step(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell,
               opts: Optional[StepOptions] = None,
               aw: Optional[adamw.AdamWConfig] = None,
               grad_transform=None):
    """Lower the cell's step on the mesh.  Returns (lowered, info dict)."""
    multi_pod = "pod" in mesh.axis_names
    if opts is None:
        opts = pick_options(cfg, mesh, cell)
    table = default_table(multi_pod, seq_shard=opts.seq_shard)
    if opts.mla_seq_shard:
        table["kvseq"] = ("model",)
    rules = Rules(mesh, table, fsdp=opts.fsdp)

    pshapes = jax.eval_shape(partial(tfm.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    if cell.step != "train":
        # serving stores weights in the compute dtype (bf16); fp32 master
        # copies only exist in the training job
        cdt = jnp.dtype(cfg.dtype)
        pshapes = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, cdt)
                       if jnp.issubdtype(l.dtype, jnp.floating) else l),
            pshapes)
    pspecs = tree_param_specs(pshapes, rules)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda s: isinstance(s, P))
    bstruct = batch_struct(cfg, cell)
    bspecs = batch_pspec(cfg, cell, rules)
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}

    info = {"options": dataclasses.asdict(opts),
            "params": param_count(cfg),
            "active_params": active_param_count(cfg)}
    if cell.step in ("decode", "prefill"):
        cs = cache_struct(cfg, cell, opts)
        info["cache_bytes"] = int(sum(
            math.prod(l.shape) * l.dtype.itemsize
            for l in jax.tree.leaves(cs)))

    with use_rules(rules):
        if cell.step == "train":
            aw = aw or adamw.AdamWConfig()
            ostruct = jax.eval_shape(adamw.init, pshapes)
            ospecs = adamw.OptState(pspecs, pspecs, P())
            osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                               is_leaf=lambda s: isinstance(s, P))
            fn = make_train_step(cfg, aw, opts, grad_transform)
            jfn = jax.jit(fn, in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, None))
            lowered = jfn.lower(pshapes, ostruct, bstruct)
        elif cell.step == "prefill":
            cstruct = cache_struct(cfg, cell, opts)
            cspecs = cache_pspecs(cstruct, rules)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                               is_leaf=lambda s: isinstance(s, P))
            fn = make_prefill_step(cfg, cell, opts)
            jfn = jax.jit(fn, in_shardings=(psh, bsh),
                          out_shardings=(None, csh))
            lowered = jfn.lower(pshapes, bstruct)
        else:  # decode
            cstruct = cache_struct(cfg, cell, opts)
            cspecs = cache_pspecs(cstruct, rules)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                               is_leaf=lambda s: isinstance(s, P))
            fn = make_decode_step(cfg, opts)
            jfn = jax.jit(fn, in_shardings=(psh, csh, bsh),
                          out_shardings=(None, csh))
            lowered = jfn.lower(pshapes, cstruct, bstruct)
    return lowered, info
