"""Serving runtime: continuous-batching engine with device-resident
clustered-KV compaction (the paper's "memory management and request
processing" made concrete).

Request processing: requests arrive with (prompt_len, max_new_tokens); the
batcher clusters them (core/request_cluster.py) into a padding-minimal
admission order; a slot-based continuous batcher then admits a request the
moment a decode slot frees (per-slot position/length tracking, early exit
at each request's own max_new_tokens) instead of padding every request in
a static batch to the longest member.

Memory management: the clustered-KV cache is compressed/refreshed with one
jitted, vmap-over-(batch ⊕ head) call (core/kv_compress.py) — no host
loops — and decode attention over [centroids ⊕ tail ring] runs in the
fused Pallas ``clustered_decode`` kernel (interpret-mode on CPU).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kv_compress
from repro.core.request_cluster import BatchPlan, Request, plan_batches, plan_fifo
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.sharding import (Rules, constrain_cache, default_table,
                            shard_cache, use_rules)


@dataclasses.dataclass
class ServerConfig:
    batch_size: int = 4            # decode slots
    max_seq: int = 256
    use_clustered_batching: bool = True
    n_request_clusters: int = 4
    greedy: bool = True
    engine: str = "continuous"     # "continuous" | "static"
    prefill_bucket: int = 16       # admission prompts are right-padded to a
                                   # multiple of this (bounds jit retraces;
                                   # causal masking keeps logits exact for
                                   # global attention / clustered KV; models
                                   # with sliding-window 'L' layers or SSM/
                                   # RG-LRU state should use 1 — pad tokens
                                   # enter the ring/recurrent state there)
    kv_compress: Optional[kv_compress.KVCompressConfig] = None
    # when set, the engine serves from a clustered KV cache end to end and
    # re-compacts every kv_compress.refresh decode steps
    mesh: Optional[Mesh] = None
    # (data, model) device mesh (launch/mesh.make_serving_mesh): decode
    # slots + their KV caches partition over "data", attention heads (and
    # the fused Pallas clustered_decode grid) over "model".  Model code
    # stays mesh-free — sharding/rules.py logical-axis annotations resolve
    # against this mesh during tracing, and a shard_map island dispatches
    # the Pallas kernel per model shard.  None = single-device engine.


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


def _is_exact_kv(node) -> bool:
    return (isinstance(node, dict) and "k" in node and "v" in node
            and "k_scale" not in node)


def _is_clustered_kv(node) -> bool:
    return isinstance(node, dict) and "k_cents" in node


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig, params):
        self.cfg = cfg
        self.scfg = scfg
        if scfg.kv_compress is not None:
            if scfg.engine != "continuous":
                raise ValueError(
                    "kv_compress serving requires the continuous engine "
                    "(the static path would silently ignore it)")
            if scfg.kv_compress.refresh < 1:
                raise ValueError(
                    "continuous serving with kv_compress needs "
                    "refresh_every >= 1 (ring entries must reach "
                    "centroids before eviction)")
        self._rules: Optional[Rules] = None
        self._n_data_shards = 1
        if scfg.mesh is not None:
            if scfg.engine != "continuous":
                raise ValueError("mesh serving requires the continuous "
                                 "engine (static batches are per-device)")
            mesh = scfg.mesh
            self._rules = Rules(mesh, default_table("pod" in mesh.axis_names))
            # replicate params across the mesh; annotations shard the
            # per-head compute, GSPMD propagation does the rest
            params = jax.device_put(params, NamedSharding(mesh, P()))
            axes = self._rules.axes_for("batch", scfg.batch_size)
            if axes:
                self._n_data_shards = math.prod(
                    mesh.shape[a] for a in axes)
        self.params = params
        self.last_stats: Dict[str, float] = {}
        # bucket-padded prefill is only exact for global attention (causal
        # mask + masked decode); sliding-window rings and SSM/RG-LRU state
        # absorb pad tokens, so those models admit at exact prompt length
        self._bucket = (1 if set(cfg.layer_pattern) & set("LMR")
                        else scfg.prefill_bucket)
        self._compact_templates: Dict[tuple, object] = {}

        def _ctx():
            return (use_rules(self._rules) if self._rules is not None
                    else contextlib.nullcontext())

        def _decode_fn(c, tk, t):
            with _ctx():
                logits, c2 = tfm.decode_step(self.params, cfg, c, tk, t)
                return logits, self._constrain(c2)

        def _prefill_fn(tk, lp):
            with _ctx():
                return tfm.prefill(self.params, cfg, tk,
                                   max_seq=scfg.max_seq, last_pos=lp)

        def _write_slot_fn(dst, src, j):
            with _ctx():
                return self._constrain(self._write_slot_impl(dst, src, j))

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(_prefill_fn)
        # donate the engine cache: admission updates one slot in place
        # instead of copying every layer's KV
        self._write_slot = jax.jit(_write_slot_fn, donate_argnums=(0,))

    def _constrain(self, cache):
        """Pin engine-cache leaves to their mesh layout inside traced fns
        (slots over data, kv heads over model) so decode/admission outputs
        keep stable shardings across steps."""
        if self._rules is None:
            return cache
        return constrain_cache(cache, self._rules)

    # ------------------------------------------------------------------
    # entry
    # ------------------------------------------------------------------

    def serve(self, requests: Sequence[Request],
              prompts: Dict[int, np.ndarray]) -> List[Completion]:
        """prompts: uid -> token array.  Returns completions per request."""
        if self.scfg.engine == "continuous":
            return self._serve_continuous(requests, prompts)
        return self._serve_static(requests, prompts)

    def _plan(self, requests: Sequence[Request]) -> BatchPlan:
        scfg = self.scfg
        if scfg.use_clustered_batching:
            return plan_batches(requests, scfg.batch_size,
                                scfg.n_request_clusters)
        return plan_fifo(requests, scfg.batch_size)

    # ------------------------------------------------------------------
    # continuous-batching engine
    # ------------------------------------------------------------------

    def _serve_continuous(self, requests, prompts) -> List[Completion]:
        cfg, scfg = self.cfg, self.scfg
        if cfg.is_encdec:
            raise NotImplementedError(
                "continuous engine serves decoder-only models")
        ccfg = scfg.kv_compress
        n = scfg.batch_size
        plan = self._plan(requests)
        order = [u for b in plan.batches for u in b]
        by_uid = {r.uid: r for r in requests}

        cache = tfm.init_cache(
            cfg, n, scfg.max_seq,
            kv_mode="clustered" if ccfg else "exact",
            kv_clusters=ccfg.n_clusters if ccfg else 512,
            kv_tail=ccfg.keep_recent if ccfg else 256)
        if self._rules is not None:
            # slot state becomes mesh-sharded arrays: slots over the data
            # axis, kv heads over model (divisibility-aware per leaf)
            cache = shard_cache(cache, self._rules)

        pos = np.zeros(n, np.int32)       # cache valid length per slot
        cur = np.zeros(n, np.int32)       # pending (unfed) token per slot
        active = np.zeros(n, bool)
        slot_uid = [-1] * n
        toks: Dict[int, List[int]] = {}
        pre_ms: Dict[int, float] = {}
        qi = 0
        decode_steps = wasted_slots = 0
        pad_toks = useful_toks = 0
        since_compact = 0
        dec_s = 0.0
        # data-shard bookkeeping: NamedSharding partitions the slot axis
        # contiguously, so slot j lives on data shard j // (n // shards).
        # Admission fills the emptiest shard first and the per-step waste
        # is tracked per shard — a fully drained shard shows up as 100%
        # waste there (per-request early exit stays host-masked; SPMD can't
        # drop one shard from the launch, but a balanced fill drains shards
        # evenly so the tail of the stream wastes as little as possible).
        shards = self._n_data_shards
        per_shard = max(n // max(shards, 1), 1)
        shard_of = lambda j: min(j // per_shard, shards - 1)  # noqa: E731
        shard_busy_steps = np.zeros(max(shards, 1), np.int64)
        shard_steps = 0

        def _pick_slot():
            """Next slot to admit into: the emptiest data shard's lowest
            free slot (occupancy recomputed per admission, so a burst of
            admissions spreads across shards instead of piling into the
            first one); plain lowest-free-slot off-mesh."""
            free = [j for j in range(n) if not active[j]]
            if not free:
                return None
            if shards <= 1:
                return free[0]
            occ = np.zeros(shards, np.int32)
            for j in range(n):
                if active[j]:
                    occ[shard_of(j)] += 1
            return min(free, key=lambda j: (occ[shard_of(j)], j))

        while True:
            while qi < len(order):
                j = _pick_slot()
                if j is None:
                    break
                uid = order[qi]
                qi += 1
                r = by_uid[uid]
                p = np.asarray(prompts[uid], np.int32)[-scfg.max_seq:]
                plen = len(p)
                bucket = min(scfg.max_seq,
                             -(-plen // self._bucket) * self._bucket)
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :plen] = p
                t0 = time.perf_counter()
                logits1, c1 = self._prefill(jnp.asarray(padded),
                                            jnp.int32(plen - 1))
                first = int(jnp.argmax(logits1, -1)[0])
                pre_ms[uid] = (time.perf_counter() - t0) * 1e3
                toks[uid] = [first]
                pad_toks += bucket - plen
                useful_toks += plen
                if r.max_new_tokens <= 1:
                    continue           # done at prefill; slot stays free
                if ccfg is not None:
                    c1 = self._clusterize(c1, cache, plen, ccfg)
                if self._rules is not None:
                    # admission: replicate the request cache across the
                    # mesh so the sharded slot-write is a local scatter
                    c1 = jax.device_put(
                        c1, NamedSharding(self._rules.mesh, P()))
                cache = self._write_slot(cache, c1, jnp.int32(j))
                cur[j], pos[j] = first, plen
                active[j] = True
                slot_uid[j] = uid
            if not active.any():
                break

            t0 = time.perf_counter()
            logits, cache = self._decode(cache, jnp.asarray(cur[:, None]),
                                         jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            dec_s += time.perf_counter() - t0
            decode_steps += 1
            wasted_slots += int((~active).sum())
            since_compact += 1
            if shards > 1:
                shard_steps += 1
                for j in range(n):
                    if active[j]:
                        shard_busy_steps[shard_of(j)] += 1

            for j in range(n):
                if not active[j]:
                    continue
                uid = slot_uid[j]
                toks[uid].append(int(nxt[j]))
                pos[j] += 1
                cur[j] = nxt[j]
                if len(toks[uid]) >= by_uid[uid].max_new_tokens:
                    active[j] = False

            if (ccfg is not None and since_compact >= ccfg.refresh
                    and active.any()):
                lengths = np.where(active, pos, 0).astype(np.int32)
                cache = self.compact_kv(cache, lengths, ccfg)
                if self._rules is not None:
                    # eviction/compaction rebuilt the clustered leaves
                    # outside the constrained decode jit — put them back
                    # on their mesh layout before the next step
                    cache = shard_cache(cache, self._rules)
                since_compact = 0

        gen_total = sum(len(v) for v in toks.values())
        # each request's first token comes from prefill; tokens/s rates
        # only the tokens the decode loop actually produced
        dec_tokens = gen_total - len(toks)
        dec_ms_tok = dec_s * 1e3 / max(gen_total, 1)
        self.last_stats = {
            "decode_steps": float(decode_steps),
            "slot_waste": wasted_slots / max(decode_steps * n, 1),
            "prefill_pad_frac": pad_toks / max(pad_toks + useful_toks, 1),
            "gen_tokens": float(gen_total),
            "decode_s": dec_s,
            "tokens_per_s": dec_tokens / max(dec_s, 1e-9),
        }
        if shards > 1:
            self.last_stats["n_data_shards"] = float(shards)
            for s in range(shards):
                self.last_stats[f"slot_waste_shard{s}"] = (
                    1.0 - shard_busy_steps[s] / (shard_steps * per_shard)
                    if shard_steps else 0.0)
        return [Completion(uid=r.uid, tokens=toks[r.uid],
                           prefill_ms=pre_ms[r.uid],
                           decode_ms=dec_ms_tok * len(toks[r.uid]))
                for r in requests]

    # admission-time conversion of a fresh (B=1) exact prefill cache into
    # the engine's clustered layout; ``template`` marks which leaves are
    # clustered (G layers) vs exact (sliding-window rings, SSM state, ...)
    def _clusterize(self, c1, template, plen: int, ccfg):
        C, R = ccfg.n_clusters, ccfg.keep_recent

        def leaf(src, tpl):
            if not (_is_clustered_kv(tpl) and _is_exact_kv(src)):
                return src
            k, v = src["k"], src["v"]
            stacked = k.ndim == 5            # (L, 1, S, H, Dh) scan region
            if stacked:
                l = k.shape[0]
                k = k.reshape((l,) + k.shape[2:])
                v = v.reshape((l,) + v.shape[2:])
            b = k.shape[0]
            # the tail-only (cov=0) form is loss-free only while every
            # prompt position survives in the ring until the first global
            # compaction, which may be up to ``refresh`` steps away —
            # longer prompts must build centroids at admission
            if plen <= R - ccfg.refresh:
                dt = k.dtype
                h, dh = k.shape[2], k.shape[3]
                out = {
                    "k_cents": jnp.zeros((b, C, h, dh), dt),
                    "v_cents": jnp.zeros((b, C, h, dh), dt),
                    "counts": jnp.zeros((b, C, h), jnp.float32),
                    # positions 0..plen-1 sit at ring slots 0..plen-1
                    "k_tail": k[:, :R],
                    "v_tail": v[:, :R],
                    "cov": jnp.zeros((b,), jnp.int32),
                }
            else:
                lengths = jnp.full((b,), plen, jnp.int32)
                out = kv_compress.compress_cache_batched(k, v, lengths, ccfg)
            if stacked:
                out = {kk: vv[:, None] for kk, vv in out.items()}
            return out

        def walk(src, tpl):
            if _is_clustered_kv(tpl):
                return leaf(src, tpl)
            if isinstance(src, dict):
                return {kk: walk(vv, tpl[kk]) for kk, vv in src.items()}
            if isinstance(src, list):
                return [walk(vv, tt) for vv, tt in zip(src, tpl)]
            return src

        return walk(c1, template)

    # scatter one (B=1) request cache into engine slot j.  prefix/tail
    # leaves carry batch on axis 0, scan-stacked leaves on axis 1.
    def _write_slot_impl(self, dst, src, j):
        def upd(axis):
            def f(d, s):
                idx = (0,) * axis + (j,) + (0,) * (d.ndim - axis - 1)
                return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), idx)
            return f

        out = dict(dst)
        for key in ("prefix", "tail"):
            out[key] = [jax.tree.map(upd(0), dc, sc)
                        for dc, sc in zip(dst[key], src[key])]
        if "scan" in dst:
            out["scan"] = jax.tree.map(upd(1), dst["scan"], src["scan"])
        return out

    # ------------------------------------------------------------------
    # memory management: batched clustered-KV compaction
    # ------------------------------------------------------------------

    def compact_kv(self, cache, t, ccfg: "kv_compress.KVCompressConfig"):
        """Compress every global-attention layer's KV into clustered form
        (median centroids + counts + exact tail ring) in single jitted
        vmap-over-(batch ⊕ head) calls — no Python loop over batch, head,
        or stacked layer.  Exact leaves are compressed from scratch;
        already-clustered leaves are incrementally re-compacted with
        warm-started centroids (streaming update between decode bursts).
        ``t`` is a scalar length or a per-slot (B,) vector.

        Only leaves that a clustered-mode cache would hold in clustered
        form (global-attention layers) are touched — sliding-window ring
        buffers, SSM/RG-LRU state, and int8 caches pass through, guided
        by a structural template (shapes only, nothing allocated)."""
        tkey = (ccfg.n_clusters, ccfg.keep_recent)
        template = self._compact_templates.get(tkey)
        if template is None:
            template = jax.eval_shape(
                lambda: tfm.init_cache(
                    self.cfg, 1, self.scfg.max_seq, kv_mode="clustered",
                    kv_clusters=ccfg.n_clusters, kv_tail=ccfg.keep_recent))
            self._compact_templates[tkey] = template

        def lengths_for(b):
            return jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))

        def compress_exact(node):
            k, v = node["k"], node["v"]
            if k.shape[-3] <= ccfg.n_clusters + ccfg.keep_recent:
                return node  # not worth compressing
            stacked = k.ndim == 5            # (L, B, S, H, Dh) scan region
            if stacked:
                l, b = k.shape[:2]
                lengths = jnp.broadcast_to(lengths_for(b), (l, b)).reshape(-1)
                out = kv_compress.compress_cache_batched(
                    k.reshape((l * b,) + k.shape[2:]),
                    v.reshape((l * b,) + v.shape[2:]), lengths, ccfg)
                return {kk: vv.reshape((l, b) + vv.shape[1:])
                        for kk, vv in out.items()}
            return kv_compress.compress_cache_batched(
                k, v, lengths_for(k.shape[0]), ccfg)

        def recompact(node):
            stacked = node["k_cents"].ndim == 5
            if stacked:
                l, b = node["k_cents"].shape[:2]
                flat = {kk: vv.reshape((l * b,) + vv.shape[2:])
                        for kk, vv in node.items()}
                lengths = jnp.broadcast_to(lengths_for(b), (l, b)).reshape(-1)
                out = kv_compress.recompact_clustered(flat, lengths, ccfg)
                return {kk: vv.reshape((l, b) + vv.shape[1:])
                        for kk, vv in out.items()}
            return kv_compress.recompact_clustered(
                node, lengths_for(node["k_cents"].shape[0]), ccfg)

        def walk(node, tpl):
            if _is_clustered_kv(tpl):
                if _is_clustered_kv(node):
                    return recompact(node)
                if _is_exact_kv(node) and node["k"].ndim in (4, 5):
                    return compress_exact(node)
                return node
            if isinstance(node, dict) and isinstance(tpl, dict):
                return {kk: walk(vv, tpl.get(kk)) for kk, vv in node.items()}
            if isinstance(node, list) and isinstance(tpl, list):
                return [walk(vv, tt) for vv, tt in zip(node, tpl)]
            return node

        return walk(cache, template)

    # ------------------------------------------------------------------
    # static batch-at-a-time path (baseline for the serve benchmark)
    # ------------------------------------------------------------------

    def _serve_static(self, requests, prompts) -> List[Completion]:
        plan = self._plan(requests)
        by_uid = {r.uid: r for r in requests}
        out: List[Completion] = []
        for batch_uids in plan.batches:
            out.extend(self._serve_batch(batch_uids, by_uid, prompts))
        self.last_stats = {"plan_waste": plan.waste}
        return out

    def _serve_batch(self, uids, by_uid, prompts) -> List[Completion]:
        cfg, scfg = self.cfg, self.scfg
        reqs = [by_uid[u] for u in uids]
        plen = max(r.prompt_len for r in reqs)
        gen = max(r.max_new_tokens for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            p = prompts[r.uid][-plen:]
            toks[i, plen - len(p):] = p  # left-pad

        t0 = time.perf_counter()
        logits, cache = self._prefill(jnp.asarray(toks), jnp.int32(plen - 1))
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        gen_toks = [new]
        for i in range(gen - 1):
            logits, cache = self._decode(cache, new, jnp.int32(plen + i))
            new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            gen_toks.append(new)
        jax.block_until_ready(new)
        t2 = time.perf_counter()

        gen_arr = np.concatenate([np.asarray(g) for g in gen_toks], axis=1)
        outs = []
        for i, r in enumerate(reqs):
            outs.append(Completion(
                uid=r.uid,
                tokens=gen_arr[i, :r.max_new_tokens].tolist(),
                prefill_ms=(t1 - t0) * 1e3 / b,
                decode_ms=(t2 - t1) * 1e3 / b))
        return outs
