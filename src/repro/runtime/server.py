"""Serving runtime: request queue → clustering batcher → decode loop,
with optional clustered-KV cache compression (memory management).

This is the "request processing" half of the paper's title made concrete:
  1. requests arrive in a queue with (prompt_len, max_new_tokens),
  2. the batcher clusters them (core/request_cluster.py) to minimize
     padding waste, 3. each batch is prefillled then decoded step by step,
  4. long caches can be compacted with the bit-serial k-medians compressor.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv_compress
from repro.core.request_cluster import BatchPlan, Request, plan_batches, plan_fifo
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServerConfig:
    batch_size: int = 4
    max_seq: int = 256
    use_clustered_batching: bool = True
    n_request_clusters: int = 4
    greedy: bool = True


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


def _tail_ring(tail_chrono, t: int, r: int):
    """Re-lay a chronological tail (positions t-r..t-1) into ring order
    (position p at slot p % r) so decode's ring indexing stays valid."""
    slots = np.mod(np.arange(t - r, t), r)
    inv = np.argsort(slots)
    return tail_chrono[:, inv]


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig, params):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._decode = jax.jit(
            lambda c, tk, t: tfm.decode_step(params, cfg, c, tk, t))

    def serve(self, requests: Sequence[Request],
              prompts: Dict[int, np.ndarray]) -> List[Completion]:
        """prompts: uid -> token array.  Returns completions per request."""
        scfg = self.scfg
        if scfg.use_clustered_batching:
            plan = plan_batches(requests, scfg.batch_size,
                                scfg.n_request_clusters)
        else:
            plan = plan_fifo(requests, scfg.batch_size)
        by_uid = {r.uid: r for r in requests}
        out: List[Completion] = []
        for batch_uids in plan.batches:
            out.extend(self._serve_batch(batch_uids, by_uid, prompts))
        return out

    def compact_kv(self, cache, t: int, ccfg: "kv_compress.KVCompressConfig"):
        """Memory-management maintenance pass: compress every global-
        attention layer's exact KV prefix into clustered form (median
        centroids + counts + exact tail).  Called between decode bursts
        (e.g. every ``ccfg.keep_recent`` steps); the returned cache plugs
        straight into decode_step (the clustered path dispatches on the
        cache contents)."""
        def compress_leaf_pair(c):
            if not (isinstance(c, dict) and "k" in c and "v" in c):
                return c
            k, v = c["k"], c["v"]
            if k.shape[1] <= ccfg.n_clusters + ccfg.keep_recent:
                return c  # not worth compressing
            b = k.shape[0]
            outs = []
            for i in range(b):
                outs.append(kv_compress.compress_cache(
                    jnp.asarray(k[i][:t]), jnp.asarray(v[i][:t]), ccfg))
            return {
                "k_cents": jnp.stack([o.k_cents.transpose(1, 0, 2)
                                      for o in outs]),
                "v_cents": jnp.stack([o.v_cents.transpose(1, 0, 2)
                                      for o in outs]),
                "counts": jnp.stack([o.counts.T for o in outs]),
                "k_tail": _tail_ring(
                    jnp.stack([o.k_tail.transpose(1, 0, 2) for o in outs]),
                    t, ccfg.keep_recent),
                "v_tail": _tail_ring(
                    jnp.stack([o.v_tail.transpose(1, 0, 2) for o in outs]),
                    t, ccfg.keep_recent),
            }

        def walk(node):
            if isinstance(node, dict) and "k" in node and "v" in node:
                if node["k"].ndim == 4:
                    return compress_leaf_pair(node)
                if node["k"].ndim == 5:  # scan-stacked: (layers, B, S, H, D)
                    n_rep = node["k"].shape[0]
                    per_layer = [compress_leaf_pair(
                        {"k": node["k"][i], "v": node["v"][i]})
                        for i in range(n_rep)]
                    if any("k_cents" not in pl for pl in per_layer):
                        return node  # too short to compress: keep exact
                    return {kk: jnp.stack([pl[kk] for pl in per_layer])
                            for kk in per_layer[0]}
            if isinstance(node, dict):
                return {kk: walk(vv) for kk, vv in node.items()}
            if isinstance(node, list):
                return [walk(vv) for vv in node]
            return node

        return walk(cache)

    def _serve_batch(self, uids, by_uid, prompts) -> List[Completion]:
        cfg, scfg = self.cfg, self.scfg
        reqs = [by_uid[u] for u in uids]
        plen = max(r.prompt_len for r in reqs)
        gen = max(r.max_new_tokens for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            p = prompts[r.uid][-plen:]
            toks[i, plen - len(p):] = p  # left-pad

        t0 = time.perf_counter()
        logits, cache = jax.jit(
            lambda tk: tfm.prefill(self.params, cfg, tk,
                                   max_seq=scfg.max_seq))(jnp.asarray(toks))
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        gen_toks = [new]
        for i in range(gen - 1):
            logits, cache = self._decode(cache, new, jnp.int32(plen + i))
            new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            gen_toks.append(new)
        jax.block_until_ready(new)
        t2 = time.perf_counter()

        gen_arr = np.concatenate([np.asarray(g) for g in gen_toks], axis=1)
        outs = []
        for i, r in enumerate(reqs):
            outs.append(Completion(
                uid=r.uid,
                tokens=gen_arr[i, :r.max_new_tokens].tolist(),
                prefill_ms=(t1 - t0) * 1e3 / b,
                decode_ms=(t2 - t1) * 1e3 / b))
        return outs
