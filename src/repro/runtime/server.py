"""Serving runtime: continuous-batching engine with device-resident
clustered-KV compaction (the paper's "memory management and request
processing" made concrete).

Request processing: requests arrive with (prompt_len, max_new_tokens); the
batcher clusters them (core/request_cluster.py) into a padding-minimal
admission order; a slot-based continuous batcher then admits a request the
moment a decode slot frees (per-slot position/length tracking, early exit
at each request's own max_new_tokens) instead of padding every request in
a static batch to the longest member.

Admission runs in one of two modes:

  * **chunked, decode-interleaved prefill** (``prefill_chunk > 0``): each
    engine step consumes one prompt chunk for at most one admitting slot
    per data shard, fused into the same launch that advances every decode
    slot by one token (mixed-mode ``decode_step`` / Pallas
    ``clustered_decode``), so admission never stalls decode and the
    prompt's KV streams straight into the already-sharded engine cache —
    in clustered form via ``kv_compress.absorb_chunk`` when the prompt
    outgrows the tail ring (compaction-aware admission with a prompt-time
    centroid budget).  No blocking prefill, no bucket padding, no B=1
    cache replication.
  * **blocking prefill** (``prefill_chunk == 0``, the baseline): a full
    right-padded prefill call per admission, then a donated slot-write.

Memory management: the clustered-KV cache is compressed/refreshed with one
jitted, vmap-over-(batch ⊕ head) call (core/kv_compress.py) — no host
loops — and decode attention over [centroids ⊕ tail ring] runs in the
fused Pallas ``clustered_decode`` kernel (interpret-mode on CPU).
Compaction runs on a **per-slot cadence**: a slot is refreshed after
``refresh_every`` of its own decode tokens, and slots whose frontier
does not move keep their summaries bit-identical (gated in
``recompact_clustered``) — each slot's state is a function of its own
token stream alone, independent of neighbours' admission timing.

Prefix sharing (``ServerConfig.prefix_share``, paged + chunked only):
admission hashes prompt prefixes at chunk boundaries into a per-data-
shard prefix cache (runtime/prefix_cache.py); a matching request adopts
the registered tail-ring pool blocks (ref-counted, copy-on-write at the
first divergent write via ``kv_pool.ensure``) and restores the absorbed
prompt centroids + coverage frontier, resuming admission mid-prompt with
greedy tokens bit-identical to unshared paged serving.

Pool pressure never kills the batch: an admission that cannot get its
blocks is deferred back to the queue, a slot whose ring write cannot be
backed stalls for the step (its packed row is simply not launched) and
retries after the next compaction give-back or prefix-cache eviction;
``PoolExhausted`` only surfaces when zero forward progress is possible.

Decode launches are **bucketed** per data shard: the physical cache holds
``shards × bucket`` slots where the bucket shrinks (powers of two) on the
end-of-stream drain — once the queue is empty and no prefill is in
flight — so a near-empty shard stops paying for dead slots.  Dead slot
content is dropped on shrink (finished requests hold no live state);
every new serve starts back at the full shape, and all admissions happen
at the full shape, so the admission traces exist at exactly one batch
size (``ensure_row`` is a defensive re-grow valve should that policy
ever change).
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import kv_compress
from repro.core import layer_state
from repro.core import retention
from repro.core.request_cluster import BatchPlan, Request, plan_batches, plan_fifo
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime import kv_pool
from repro.runtime import prefix_cache as prefix_mod
from repro.runtime import template_store as template_mod
from repro.runtime.scheduler import SLOConfig, SLOScheduler, SwapRecord
from repro.runtime import telemetry as tele_mod
from repro.runtime.telemetry import TelemetryConfig
from repro.sharding import (Rules, constrain_cache, default_table,
                            place_admission, place_block_tables,
                            place_prefix_snapshot, place_swap_payload,
                            serving_param_specs, shard_cache,
                            shardings_from_specs, use_rules)
from repro.sharding.rules import _key_str as _key_name


@dataclasses.dataclass
class ServerConfig:
    batch_size: int = 4            # decode slots
    max_seq: int = 256
    use_clustered_batching: bool = True
    n_request_clusters: int = 4
    greedy: bool = True
    engine: str = "continuous"     # "continuous" | "static"
    prefill_bucket: int = 16       # admission prompts are right-padded to a
                                   # multiple of this (bounds jit retraces;
                                   # causal masking keeps logits exact for
                                   # global attention / clustered KV; models
                                   # with sliding-window 'L' layers or SSM/
                                   # RG-LRU state should use 1 — pad tokens
                                   # enter the ring/recurrent state there).
                                   # Blocking admission only.
    prefill_chunk: int = 0         # >0: chunked prefill interleaved with
                                   # decode — each engine step feeds one
                                   # prompt chunk of this many tokens for at
                                   # most one admitting slot per data shard,
                                   # fused with the decode launch.  Exact
                                   # positions, so no bucket padding.
                                   # Covers both layer-state families
                                   # (G/L ring-KV layers and M/R
                                   # recurrent-state layers — see
                                   # core/layer_state.py); must be
                                   # <= kv_compress.keep_recent when
                                   # serving clustered.
    kv_compress: Optional[kv_compress.KVCompressConfig] = None
    # when set, the engine serves from a clustered KV cache end to end and
    # re-compacts every kv_compress.refresh decode steps
    paged: Optional[kv_pool.PagedKVConfig] = None
    # paged clustered-KV memory manager: the exact tail rings live in a
    # per-shard block pool (block_size positions per block, pool_blocks
    # blocks per data shard) behind per-slot block tables — blocks are
    # allocated on admission / right before the write that needs them,
    # recycled on request exit, and returned mid-stream once compaction
    # covers them (runtime/kv_pool.py).  Decode runs as PACKED ragged
    # launches: one row per real (slot, position) pair instead of
    # slots × chunk, so mixed prefill+decode compute scales with real
    # tokens.  Requires kv_compress (the clustered path is what paging
    # replaces); greedy outputs are token-identical to the dense engine.
    prefix_share: Optional[prefix_mod.PrefixShareConfig] = None
    # prefix-sharing paged admission: prompts are hashed at chunk
    # boundaries into a per-data-shard prefix cache
    # (runtime/prefix_cache.py); a new request whose prompt matches a
    # registered prefix adopts the matching tail-ring pool blocks
    # (ref-counted, copy-on-write at the first divergent write) and
    # restores the absorbed prompt centroids + coverage frontier instead
    # of re-prefilling — greedy tokens stay bit-identical to unshared
    # paged serving while shared-prefix bursts skip most prompt chunks
    # (TTFT) and share tail blocks (KV bytes).  Requires ``paged`` +
    # ``prefill_chunk``.
    template_store: Optional[object] = None
    # persistent cross-serve template store (runtime/template_store.py):
    # a TemplateStoreConfig (the server owns a private store) or a
    # TemplateStore instance (shareable across servers; epoch stamping
    # invalidates it whenever the model/KV config/pool it was warmed
    # against changes).  Subsumes ``prefix_share`` — same block-adopting
    # admission fast path, but entries and their pinned pool blocks
    # survive between serve() calls, eviction is hit-scored instead of
    # LRU, and incoming traffic is clustered online for steering.  The
    # end-of-serve pool invariant becomes
    # ``allocated() == store.pinned_blocks()`` (reported as
    # ``pool_blocks_end == 0`` after subtracting the pins); use
    # ``Server.invalidate_templates()`` to drain the pins explicitly.
    scheduler: Optional[SLOConfig] = None
    # SLO-aware scheduling (runtime/scheduler.py): requests carry
    # priorities/deadlines (Request.priority / .deadline_ms); under slot
    # or pool pressure the engine preempts the cheapest lower-priority
    # in-flight slot — its clustered snapshot + mapped tail blocks swap
    # to host memory and the blocks return to the pool — and re-admits
    # it mid-stream bit-identically when capacity returns.  Best-effort
    # load is deferred/shed to protect the high class's TTFT; the
    # brownout ladder (defer → preempt → swap-in → shed) runs ahead of
    # PoolExhausted, which then only fires when all remaining work is
    # the protected class.  Requires the paged clustered engine
    # (kv_compress= + paged=, all-'G' layers).
    telemetry: Optional[TelemetryConfig] = None
    # serving telemetry (runtime/telemetry.py): last_stats is always
    # regenerated from the typed metrics registry; telemetry.trace
    # additionally records host-side request-lifecycle spans and
    # engine-step events into Server.last_trace (exportable as JSONL or
    # Chrome trace JSON via Server.export_trace — loadable in Perfetto).
    # Tracing never runs inside jit and never touches device state, so
    # greedy tokens are bit-identical with tracing on vs off.
    mesh: Optional[Mesh] = None
    # (data, model) device mesh (launch/mesh.make_serving_mesh): decode
    # slots + their KV caches partition over "data", attention heads (and
    # the fused Pallas clustered_decode grid) over "model".  Model code
    # stays mesh-free — sharding/rules.py logical-axis annotations resolve
    # against this mesh during tracing, and a shard_map island dispatches
    # the Pallas kernel per model shard.  None = single-device engine.


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_ms: float              # wall-clock time to first token (TTFT)
    decode_ms: float
    shed: bool = False             # dropped by SLO brownout: tokens are
                                   # partial (or empty if never admitted)


def _is_exact_kv(node) -> bool:
    return (isinstance(node, dict) and "k" in node and "v" in node
            and "k_scale" not in node)


def _is_clustered_kv(node) -> bool:
    return isinstance(node, dict) and "k_cents" in node


def _pow2ceil(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def _slot_resize(x, axis: int, shards: int, ob: int, nb: int):
    """Resize one cache leaf's slot axis from shards*ob to shards*nb rows,
    keeping each data shard's block contiguous (slice drops dead high
    slots; pad appends zero slots).  Reshape-based so a NamedSharding
    over the slot axis stays shard-local."""
    lead, rest = x.shape[:axis], x.shape[axis + 1:]
    xr = x.reshape(lead + (shards, ob) + rest)
    if nb < ob:
        xr = jax.lax.slice_in_dim(xr, 0, nb, axis=axis + 1)
    elif nb > ob:
        pad = [(0, 0)] * xr.ndim
        pad[axis + 1] = (0, nb - ob)
        xr = jnp.pad(xr, pad)
    return xr.reshape(lead + (shards * nb,) + rest)


def _percentile_ms(vals: List[float], q: float) -> float:
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals), q) * 1e3)


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig, params):
        self.cfg = cfg
        self.scfg = scfg
        if scfg.kv_compress is not None:
            if scfg.engine != "continuous":
                raise ValueError(
                    "kv_compress serving requires the continuous engine "
                    "(the static path would silently ignore it)")
            if scfg.kv_compress.refresh < 1:
                raise ValueError(
                    "continuous serving with kv_compress needs "
                    "refresh_every >= 1 (ring entries must reach "
                    "centroids before eviction)")
        self._paged = scfg.paged
        if self._paged is not None:
            if scfg.engine != "continuous":
                raise ValueError("paged serving requires the continuous "
                                 "engine")
            if scfg.kv_compress is not None:
                if scfg.kv_compress.keep_recent % self._paged.block_size:
                    raise ValueError(
                        f"block_size {self._paged.block_size} must divide "
                        f"keep_recent {scfg.kv_compress.keep_recent} (ring "
                        "offsets map to whole blocks)")
            elif scfg.max_seq % self._paged.block_size:
                raise ValueError(
                    f"block_size {self._paged.block_size} must divide "
                    f"max_seq {scfg.max_seq}: paged serving without "
                    "kv_compress is exact-KV under QuotaRetention — the "
                    "full sequence is backed by whole blocks reserved as "
                    "a per-slot budget at admission")
            report = cfg.serving_gate_report()
            if report is not None:
                raise ValueError("paged serving: " + report)
            if not layer_state.families_for(cfg).has_ring:
                raise ValueError(
                    "paged serving needs at least one ring-family layer: "
                    "recurrent-state layers ('M'/'R') carry fixed-size "
                    "per-slot state that is never pool-backed, so a "
                    "pure-recurrent pattern has nothing to page — serve "
                    "dense chunked instead (prefill_chunk= without paged=)")
        # paged without kv_compress = exact-KV serving under a block
        # quota (core/retention.QuotaRetention): the cache keeps the
        # clustered LAYOUT (one permanently-dead centroid, counts == 0 ⇒
        # masked) with a full-depth tail ring, cov pinned at 0 so every
        # position stays exact, and blocks retire only at request exit
        self._kv_layout = scfg.kv_compress
        if self._paged is not None and scfg.kv_compress is None:
            self._kv_layout = kv_compress.KVCompressConfig(
                n_clusters=1, keep_recent=scfg.max_seq, refresh_every=0)
        self._pshare = scfg.prefix_share
        self._store: Optional[template_mod.TemplateStore] = None
        if scfg.template_store is not None:
            if self._pshare is not None:
                raise ValueError(
                    "template_store subsumes prefix_share (same adopting "
                    "admission path, persistent entries) — set only one")
            ts = scfg.template_store
            self._store = (ts if isinstance(ts, template_mod.TemplateStore)
                           else template_mod.TemplateStore(ts))
            self._pshare = self._store.share
        if self._pshare is not None:
            if (self._paged is None or not scfg.prefill_chunk
                    or scfg.kv_compress is None
                    or set(cfg.layer_pattern) - set("GMR")):
                raise ValueError(
                    "prefix_share/template_store requires the paged "
                    "clustered engine with chunked prefill over snapshot-"
                    "coverable layers ('G' clustered rings plus 'M'/'R' "
                    "recurrent state; 'L' window rings are not in "
                    "snapshots) — kv_compress= + paged= + prefill_chunk=: "
                    "block-granular sharing needs the block pool's ref "
                    "counts, slot snapshots restore clustered summaries "
                    "and recurrent state only, and prefix-pure "
                    "registration points only exist on the chunked "
                    "admission schedule")
        self._slo = scfg.scheduler
        if self._slo is not None:
            if (self._paged is None or scfg.kv_compress is None
                    or set(cfg.layer_pattern) - set("GMR")
                    or scfg.engine != "continuous"):
                raise ValueError(
                    "scheduler= (SLO-aware preemption) requires the "
                    "paged clustered continuous engine over snapshot-"
                    "coverable layers ('G' clustered rings plus 'M'/'R' "
                    "recurrent state; 'L' window rings are not in "
                    "snapshots) — kv_compress= + paged=: swap snapshots "
                    "restore clustered summaries and recurrent state "
                    "only, and preemption frees pool blocks — the dense "
                    "and exact engines have nothing to swap")
        self._chunk = scfg.prefill_chunk
        if self._chunk:
            if scfg.engine != "continuous":
                raise ValueError("chunked prefill requires the continuous "
                                 "engine")
            report = cfg.serving_gate_report()
            if report is not None:
                raise ValueError("chunked prefill: " + report)
            if (scfg.kv_compress is not None
                    and self._chunk > scfg.kv_compress.keep_recent):
                raise ValueError(
                    "prefill_chunk must fit the exact tail ring "
                    "(<= kv_compress.keep_recent): a chunk's K/V lands in "
                    "the ring before absorb_chunk can cover it")
        self._rules: Optional[Rules] = None
        self._n_data_shards = 1
        if scfg.mesh is not None:
            if scfg.engine != "continuous":
                raise ValueError("mesh serving requires the continuous "
                                 "engine (static batches are per-device)")
            mesh = scfg.mesh
            self._rules = Rules(mesh, default_table("pod" in mesh.axis_names))
            # param placement: MoE routed-expert banks distribute over
            # the model axis (serving_param_specs — the one family of
            # leaves whose replication cost dominates); everything else
            # replicates and the annotate/shard_map islands shard the
            # per-head compute, GSPMD propagation does the rest
            params = jax.device_put(
                params, shardings_from_specs(
                    mesh, serving_param_specs(params, self._rules)))
            axes = self._rules.axes_for("batch", scfg.batch_size)
            if axes:
                self._n_data_shards = math.prod(
                    mesh.shape[a] for a in axes)
        self.params = params
        self.last_stats: Dict[str, float] = {}
        # typed metrics registry + lifecycle tracer: last_stats is a
        # flat view regenerated from the registry at the end of every
        # serve, so per-serve dynamic keys (template_cluster*,
        # slot_waste_shard*, sched_*) from a previous serve or mesh
        # shape can never leak into the next serve's stats
        self.metrics = tele_mod.MetricsRegistry()
        self._tele = scfg.telemetry or TelemetryConfig()
        self.tracer = (tele_mod.Tracer(self._tele.max_events)
                       if self._tele.trace else None)
        self.last_trace: List[dict] = []
        # cross-serve template persistence: the pool (host tables/refs)
        # and the device engine cache that carry the store's pinned
        # blocks between serve() calls.  The config epoch stamps every
        # input a registered snapshot depends on — a store rebound under
        # a different model/KV config/geometry or different weight BYTES
        # invalidates instead of adopting stale state.  The weight stamp
        # is a content hash, not object identity, so reloaded identical
        # params (a new pytree with the same bytes) keep a warm store.
        self._tmpl_pool: Optional[kv_pool.BlockPool] = None
        self._tmpl_cache = None
        self._store_epoch = (repr(cfg), repr(scfg.kv_compress),
                             repr(scfg.paged), scfg.prefill_chunk,
                             scfg.max_seq, scfg.batch_size,
                             self._n_data_shards,
                             self._params_digest(self.params))
        # layer-state families (core/layer_state.py): which state each
        # layer carries per slot — ring-KV ('G'/'L', retention-governed)
        # vs fixed-size recurrent state ('M'/'R', checkpointed whole).
        # None = the pattern has kinds outside both families; every
        # engine path that consults families has already been rejected
        # by a gate for such configs.
        try:
            self._families = layer_state.families_for(cfg)
        except ValueError:
            self._families = None
        self._has_recurrent = (self._families is not None
                               and self._families.has_recurrent)
        # bucket-padded prefill is only exact for global attention (causal
        # mask + masked decode); sliding-window rings and SSM/RG-LRU state
        # absorb pad tokens, so those models admit at exact prompt length
        self._bucket = (1 if set(cfg.layer_pattern) & set("LMR")
                        else scfg.prefill_bucket)
        self._compact_templates: Dict[tuple, object] = {}
        self._resize_jits: Dict[tuple, object] = {}

        def _ctx():
            return (use_rules(self._rules) if self._rules is not None
                    else contextlib.nullcontext())

        def _decode_fn(c, tk, t):
            with _ctx():
                logits, c2 = tfm.decode_step(self.params, cfg, c, tk, t)
                return logits, self._constrain(c2)

        def _mixed_fn(c, tk, t, cl):
            with _ctx():
                logits, c2 = tfm.decode_step(self.params, cfg, c, tk, t,
                                             chunk_len=cl)
                return logits, self._constrain(c2)

        def _prefill_fn(tk, lp):
            with _ctx():
                # recurrent layers prefill SEQUENTIALLY when served: the
                # parallel scan forms (ssd_chunked / associative scan)
                # are mathematically equal but not bitwise equal to
                # stepwise decode, and serving pins chunked/paged tokens
                # bit-identical to blocking one-at-a-time decode
                return tfm.prefill(self.params, cfg, tk,
                                   max_seq=scfg.max_seq, last_pos=lp,
                                   recurrent_mode=("sequential"
                                                   if self._has_recurrent
                                                   else "scan"))

        def _write_slot_fn(dst, src, j):
            with _ctx():
                return self._constrain(self._write_slot_impl(dst, src, j))

        def _reset_slot_fn(c, j):
            with _ctx():
                return self._constrain(self._reset_slot_impl(c, j))

        self._decode = jax.jit(_decode_fn)
        self._mixed = jax.jit(_mixed_fn)
        self._prefill = jax.jit(_prefill_fn)
        # donate the engine cache: admission updates one slot in place
        # instead of copying every layer's KV
        self._write_slot = jax.jit(_write_slot_fn, donate_argnums=(0,))
        self._reset_slot = jax.jit(_reset_slot_fn, donate_argnums=(0,))
        ccfg = scfg.kv_compress

        def _absorb_fn(c, j, lengths, target):
            with _ctx():
                return self._constrain(
                    self._absorb_impl(c, j, lengths, target, ccfg))

        self._absorb = jax.jit(_absorb_fn, donate_argnums=(0,))

        if self._paged is not None:
            blk = self._paged.block_size

            def _packed_fn(c, tk, rs, rp, rtw, rcidx, bt, width):
                with _ctx():
                    logits, c2 = tfm.decode_step_packed(
                        self.params, cfg, c, tk, rs, rp, rtw, rcidx, bt,
                        block_size=blk, width=width)
                    return logits, self._constrain(c2)

            def _write_slot_paged_fn(dst, src, j, bt_row):
                with _ctx():
                    return self._constrain(
                        self._write_slot_paged_impl(dst, src, j, bt_row,
                                                    blk))

            def _absorb_paged_fn(c, j, lengths, target, bt_row):
                with _ctx():
                    return self._constrain(self._absorb_paged_impl(
                        c, j, lengths, target, bt_row, ccfg))

            def _compact_paged_fn(c, lengths, bt):
                with _ctx():
                    return self._constrain(
                        self._compact_paged_impl(c, lengths, bt, ccfg))

            def _snap_fn(c, j):
                with _ctx():
                    return tfm.clustered_slot_state(c, j)

            def _restore_fn(c, snap, j):
                with _ctx():
                    return self._constrain(
                        tfm.restore_clustered_slot_state(c, snap, j))

            def _cow_fn(c, src, dst):
                with _ctx():
                    return self._constrain(self._cow_impl(c, src, dst))

            def _swap_out_fn(c, j, bt_row):
                with _ctx():
                    return (tfm.clustered_slot_state(c, j),
                            self._gather_swap_tails(c, bt_row))

            def _swap_in_fn(c, snap, tails, j, bt_row):
                with _ctx():
                    c2 = tfm.restore_clustered_slot_state(c, snap, j)
                    return self._constrain(
                        self._scatter_swap_tails(c2, tails, bt_row))

            # ``width`` (max chunk index + 1, sequencing sliding-window
            # ring commits) is static: exactly two traces — the mixed
            # shape (width = prefill_chunk) and pure decode (width = 1)
            self._decode_packed = jax.jit(_packed_fn, donate_argnums=(0,),
                                          static_argnums=(7,))
            self._write_slot_paged = jax.jit(_write_slot_paged_fn,
                                             donate_argnums=(0,))
            self._absorb_paged = jax.jit(_absorb_paged_fn,
                                         donate_argnums=(0,))
            self._compact_paged = jax.jit(_compact_paged_fn,
                                          donate_argnums=(0,))
            self._snap_slot = jax.jit(_snap_fn)
            self._restore_slot_state = jax.jit(_restore_fn,
                                               donate_argnums=(0,))
            self._cow = jax.jit(_cow_fn, donate_argnums=(0,))
            # preemption swap: out gathers one slot's clustered snapshot
            # plus its full tail-ring block row (the host keeps only the
            # mapped blocks' bytes meaningful; unmapped rows gather the
            # shard-base alias garbage the masks already exclude); in
            # restores the snapshot and scatters ONLY freshly-allocated
            # blocks back (re-adopted blocks may be shared — writing
            # them, even with identical bytes, would break the COW
            # protocol — and their payloads are provably unchanged)
            self._swap_out = jax.jit(_swap_out_fn)
            self._swap_in = jax.jit(_swap_in_fn, donate_argnums=(0,))

    def _constrain(self, cache):
        """Pin engine-cache leaves to their mesh layout inside traced fns
        (slots over data, kv heads over model) so decode/admission outputs
        keep stable shardings across steps."""
        if self._rules is None:
            return cache
        return constrain_cache(cache, self._rules)

    # ------------------------------------------------------------------
    # entry
    # ------------------------------------------------------------------

    def serve(self, requests: Sequence[Request],
              prompts: Dict[int, np.ndarray]) -> List[Completion]:
        """prompts: uid -> token array.  Returns completions per request."""
        if self.scfg.engine == "continuous":
            return self._serve_continuous(requests, prompts)
        return self._serve_static(requests, prompts)

    def export_trace(self, path: str, fmt: str = "chrome") -> None:
        """Write the last serve's lifecycle trace (requires
        ``ServerConfig.telemetry.trace``): ``fmt="chrome"`` emits Chrome
        trace-event JSON loadable in Perfetto (one process per data
        shard, spans nested under slot threads, last_stats embedded for
        offline reconciliation); ``fmt="jsonl"`` emits the raw event
        log, one JSON object per line."""
        if fmt == "chrome":
            tele_mod.write_chrome_trace(self.last_trace, path,
                                        n_shards=self._n_data_shards,
                                        stats=self.last_stats)
        elif fmt == "jsonl":
            tele_mod.write_jsonl(
                self.last_trace, path,
                meta={"n_shards": self._n_data_shards,
                      "last_stats": {k: float(v)
                                     for k, v in self.last_stats.items()}})
        else:
            raise ValueError(f"unknown trace format {fmt!r} "
                             "(expected 'chrome' or 'jsonl')")

    def invalidate_templates(self) -> None:
        """Explicitly drop every persistent template entry, releasing
        the pool blocks the store pinned across serves — afterwards the
        pool is fully drained (``allocated() == 0``; there are no other
        block holders between serves).  The warmed device cache is
        dropped too: with no pins its template payloads are unreachable
        and the next serve starts cold."""
        if self._store is not None:
            self._store.invalidate()
        if self._tmpl_pool is not None:
            assert self._tmpl_pool.allocated() == 0, \
                "template pins released but pool still holds blocks"
        self._tmpl_pool = None
        self._tmpl_cache = None

    def _plan(self, requests: Sequence[Request]) -> BatchPlan:
        scfg = self.scfg
        if scfg.use_clustered_batching:
            return plan_batches(requests, scfg.batch_size,
                                scfg.n_request_clusters)
        return plan_fifo(requests, scfg.batch_size)

    # ------------------------------------------------------------------
    # continuous-batching engine
    # ------------------------------------------------------------------

    def _serve_continuous(self, requests, prompts) -> List[Completion]:
        cfg, scfg = self.cfg, self.scfg
        if cfg.is_encdec:
            raise NotImplementedError(
                "continuous engine serves decoder-only models")
        t0_serve = time.perf_counter()
        # per-serve registry window: every non-persist metric from the
        # previous serve (including dynamic per-cluster / per-shard /
        # sched_* keys) is dropped here; lifetime *_total metrics survive
        reg = self.metrics
        reg.begin_serve()
        tr = self.tracer
        _annot = (tele_mod.annotation if self._tele.jax_profiler
                  else (lambda _n: contextlib.nullcontext()))
        ccfg = scfg.kv_compress
        # the cache LAYOUT (clustered leaves + tail ring geometry) is
        # distinct from the retention policy served on top of it: ccfg ⇒
        # FrontierRetention, paged-sans-ccfg ⇒ QuotaRetention over the
        # same leaf shapes with a full-depth ring
        layout = self._kv_layout
        chunk = self._chunk
        n = scfg.batch_size
        plan = self._plan(requests)
        order = [u for b in plan.batches for u in b]
        by_uid = {r.uid: r for r in requests}
        if (self.scfg.scheduler is not None
                and self.scfg.scheduler.priority_admission):
            # admission control: the protected class admits ahead of
            # best-effort work regardless of queue position (stable
            # within a class, so the batcher's padding-minimal order
            # survives inside each class).  Tokens are unaffected —
            # per-slot state is a function of the slot's own stream —
            # only who waits.
            order.sort(key=lambda uid: -by_uid[uid].priority)

        # data-shard bookkeeping: NamedSharding partitions the slot axis
        # contiguously, so logical slot j lives on data shard
        # j // per_shard at within-shard index j % per_shard.  The cache
        # physically holds shards * bucket rows (bucketed launches):
        # logical j maps to physical row shard*bucket + idx, valid while
        # idx < bucket.  Admission fills the emptiest shard's lowest index
        # first, keeping buckets tight; a drained shard's dead high slots
        # are sliced away (their content is dead state).
        shards = self._n_data_shards
        per_shard = max(n // max(shards, 1), 1)
        bucket = per_shard
        shard_of = lambda j: min(j // per_shard, shards - 1)  # noqa: E731
        idx_of = lambda j: j % per_shard                      # noqa: E731

        def phys(j):
            return shard_of(j) * bucket + idx_of(j)

        if tr is not None:
            tr.begin_serve(t0_serve, max(shards, 1))
            if self._families is not None:
                # name the layer-state families this serve runs with so
                # offline trace consumers can segment span populations
                # (swap_out spans carry state_bytes, engine steps advance
                # recurrent state inside the same launch) by family mix
                tr.event("state_families", tid="engine", t=t0_serve,
                         ring="".join(sorted(self._families.ring.kinds)),
                         recurrent="".join(
                             sorted(self._families.recurrent.kinds)))
            for qpos, quid in enumerate(order):
                qr = by_uid[quid]
                tr.event("queued", tid="queue", uid=quid, t=t0_serve,
                         queue_pos=qpos, priority=qr.priority,
                         prompt_len=qr.prompt_len)

        # paged memory manager: tail rings live in a per-shard block pool
        # behind per-slot block tables; the launch bucket never shrinks
        # (packed rows already make compute ∝ real tokens, so the slot
        # axis stays at one traced shape)
        paged = self._paged
        pool = None
        pcache = None
        cache = None
        store = self._store
        if paged is not None:
            parked = store.parked if store is not None else None
            if (parked is not None and parked[2] == self._store_epoch
                    and parked[3] == max(shards, 1)):
                # warm cross-serve start: the parked pool and device
                # cache carry the store's pinned template blocks.  The
                # canonical copy lives on the STORE keyed by epoch, so
                # a different Server instance under the same epoch
                # (weights content-hashed — a reloaded identical pytree
                # counts) adopts it too.  Ownership is taken eagerly
                # (the slot is nulled) so a serve that dies mid-flight
                # can never leave a half-donated cache behind — the
                # next serve comes up cold and bind() invalidates the
                # orphaned entries.
                pool, cache = parked[0], parked[1]
                store.parked = None
                self._tmpl_pool = self._tmpl_cache = None
                pool.reset_peaks()
            else:
                pool = kv_pool.BlockPool(n, layout.keep_recent, paged,
                                         n_shards=max(shards, 1),
                                         slots_per_shard=per_shard,
                                         full_tail_resident=ccfg is not None)
            if store is not None:
                # epoch-checked attach: a store warmed under any other
                # config/model/pool is invalidated here, never adopted
                store.bind(self._store_epoch, max(shards, 1), pool)
                pcache = store
            elif self._pshare is not None:
                pcache = prefix_mod.PrefixCache(self._pshare,
                                                max(shards, 1), pool)
        if cache is None:
            cache = tfm.init_cache(
                cfg, n, scfg.max_seq,
                kv_mode="clustered" if layout else "exact",
                kv_clusters=layout.n_clusters if layout else 512,
                kv_tail=layout.keep_recent if layout else 256,
                kv_pool_blocks=pool.n_blocks if pool else 0,
                kv_block_size=paged.block_size if paged else 0)
            if self._rules is not None:
                # slot state becomes mesh-sharded arrays: slots over the
                # data axis, kv heads over model (divisibility-aware per
                # leaf; the paged pool's block axis shards over data
                # like slots)
                cache = shard_cache(cache, self._rules)
        # per-serve stats are deltas against these marks: a persistent
        # store carries lifetime hit/alloc counters across serves, and
        # reporting the raw totals would double-count every serve after
        # the first (the lifetime view stays available as template_*)
        hits0 = pcache.hits if pcache is not None else 0
        reused0 = pcache.tokens_reused if pcache is not None else 0
        pool_mark = ((pool.n_allocs, pool.n_frees, pool.n_retains,
                      pool.n_cow) if pool is not None else (0, 0, 0, 0))
        # SLO scheduler: one per serve — the swap backlog never outlives
        # the request stream (every parked request resumes or sheds
        # before the serve returns), so cross-serve template state is
        # untouched by preemption
        slo_cfg = self._slo
        slo = SLOScheduler(slo_cfg, n) if slo_cfg is not None else None

        pos = np.zeros(n, np.int32)       # cache valid length per slot
        cur = np.zeros(n, np.int32)       # pending (unfed) token per slot
        active = np.zeros(n, bool)        # decoding
        admitting = np.zeros(n, bool)     # chunked prefill in flight
        fed = np.zeros(n, np.int32)       # prompt tokens streamed so far
        # retention policies — WHAT each layer's cache retains, decoupled
        # from where the bytes live (core/retention.py):
        #   fr     'G' layers, clustered: retire behind the coverage
        #          frontier (owns the host cov mirror, kept in lockstep
        #          with the device cov by replaying the same formulas)
        #   quota  'G' layers, exact paged: retire nothing mid-flight;
        #          a per-slot block budget reserved at admission
        #   wr     'L' layers: retire behind the sliding window (virtual
        #          — the dense ring overwrite reclaims storage — but it
        #          drives the kv_retired_window accounting)
        #   rr     'M'/'R' layers: fixed-size recurrent state folds every
        #          position — nothing retires, a named no-op whose
        #          diagnostics keep the kv_retired_recurrent invariant
        #          explicit
        fr = (retention.FrontierRetention(n, ccfg)
              if ccfg is not None else None)
        quota = (retention.QuotaRetention(paged.block_size,
                                          pool.blocks_per_slot)
                 if pool is not None and ccfg is None else None)
        wr = (retention.WindowRetention(cfg.sliding_window, n)
              if "L" in cfg.layer_pattern and cfg.sliding_window else None)
        rr = (retention.RecurrentRetention(
                  tuple(sorted(self._families.recurrent.kinds)))
              if self._has_recurrent else None)
        sweep_policy = fr if fr is not None else quota
        cov_of = fr.frontier if fr is not None else (lambda j: 0)
        kv_retired = {"frontier": 0, "window": 0, "quota": 0}
        slot_uid = [-1] * n
        prompt_np: Dict[int, np.ndarray] = {}
        toks: Dict[int, List[int]] = {}
        pre_ms: Dict[int, float] = {}
        token_t: Dict[int, List[float]] = {}
        # tracer tenancy bookkeeping: one "run" span per (slot, tenancy)
        # segment — admit/resume opens it, finish/shed/preempt closes it.
        # Token deltas across a uid's segments sum to its final count, so
        # validate_trace can reconcile run spans against gen_tokens.
        seg: List[Optional[tuple]] = [None] * n

        def slot_tid(j):
            return f"slot{idx_of(j)}"

        def tr_open(j, uid, t, how, p0=0):
            if tr is None:
                return
            seg[j] = (t, how, uid, len(toks.get(uid, ())), int(p0))

        def tr_close(j, t, why):
            """Close slot j's tenancy span.  Called BEFORE the slot's
            blocks are freed so blocks_held reflects the tenancy."""
            if tr is None or seg[j] is None:
                return
            t0s, how, uid, tok0, p0 = seg[j]
            seg[j] = None
            held = (pool.mapped_blocks(j) if pool is not None else 0)
            tr.span("run", t0s, t, pid=shard_of(j), tid=slot_tid(j),
                    uid=uid, start=how, end=why,
                    tokens=len(toks.get(uid, ())) - tok0, pos0=p0,
                    pos1=int(max(int(fed[j]), int(pos[j]))),
                    blocks_held=held)

        def tr_brownout(rung, why, **args):
            """Instant event naming the brownout rung taken and WHY —
            which headroom/pool check failed, which victim was chosen."""
            if tr is not None:
                tr.event("brownout", tid="engine", rung=rung, why=why,
                         **args)

        qi = 0
        decode_steps = wasted_slots = 0
        rows_launched = 0
        pad_toks = useful_toks = 0
        n_chunks = n_absorbs = n_compacts = 0
        # compaction cadence is per-slot decode progress, not engine
        # steps: a slot's ring only advances when that slot decodes, so
        # chunk-feed steps for OTHER slots must not inflate the schedule
        # (the eviction-safety invariant is per slot: cov >= t - R +
        # refresh after at most ``refresh`` of its own tokens)
        since_tok = np.zeros(n, np.int32)
        dec_s = 0.0
        R = layout.keep_recent if layout else 0
        shard_busy_steps = np.zeros(max(shards, 1), np.int64)
        shard_steps = 0
        # packed-launch accounting: real (slot, position) pairs fed vs
        # rows×width actually launched — the dense bucketed path pays
        # slots × chunk on mixed steps, the paged packed path only its
        # per-shard row bucket
        launch_real = launch_padded = 0
        # KV-allocation accounting (clustered serving): live ring tokens
        # vs allocated ring capacity, so paged and dense runs report
        # comparable occupancy / fragmentation / peak-bytes numbers
        kv_live_sum = kv_alloc_sum = 0
        kv_alloc_peak = 0
        # prefix sharing: peak count of extra logical block mappings —
        # blocks-worth of tail KV that sharing avoided materializing
        kv_shared_peak = 0
        tail_bpt = self._tail_bytes_per_token(cache) if layout else 0
        # recurrent-family byte price: the whole fixed-size state one
        # slot carries — constant over the stream, swapped whole, never
        # pool-backed — added to every victim's cost and swap payload
        rec_state_b = (layer_state.recurrent_state_bytes(cache, n)
                       if self._has_recurrent else 0)

        def resize_to(nb):
            nonlocal cache, bucket
            if nb == bucket:
                return
            cache = self._resize_cache(cache, bucket, nb)
            bucket = nb

        bt_cache = [None]

        def bt_device():
            """Device copy of the block table, re-uploaded only when the
            allocator mutated it since the last launch (steady-state
            decode reuses the cached array)."""
            if bt_cache[0] is None or pool.dirty:
                arr = jnp.asarray(pool.table_for_read())
                if self._rules is not None:
                    arr = place_block_tables(arr, self._rules)
                bt_cache[0] = arr
                pool.dirty = False
            return bt_cache[0]

        def occupancy():
            occ = np.zeros(max(shards, 1), np.int32)
            for j in range(n):
                if active[j] or admitting[j]:
                    occ[shard_of(j)] += 1
            return occ

        def sweep_covered(s):
            """Give back every block shard ``s``'s retention policy has
            already retired (idempotent: under FrontierRetention,
            absorb/compaction normally do this the moment ``cov``
            advances, so a sweep only recovers blocks under pool
            pressure; under QuotaRetention nothing retires mid-flight and
            the sweep is a no-op by construction).  Each slot's UPCOMING
            write blocks are protected — mid-step they may be allocated
            but not yet written (stale claims look dead), and freeing one
            would only make ``ensure`` re-allocate it and the reclaim
            loop spin."""
            freed = 0
            for j in range(n):
                if shard_of(j) != s:
                    continue
                if admitting[j]:
                    plen = len(prompt_np[slot_uid[j]])
                    cl = int(min(chunk, plen - fed[j])) if chunk else 0
                    sweep_policy.protect_write(j, kv_pool.write_blocks(
                        int(fed[j]), max(cl, 1), R, paged.block_size))
                    freed += pool.free_retired(j, int(fed[j]),
                                               sweep_policy)
                    sweep_policy.clear_protection(j)
                elif active[j]:
                    sweep_policy.protect_write(j, kv_pool.write_blocks(
                        int(pos[j]), 1, R, paged.block_size))
                    freed += pool.free_retired(j, int(pos[j]),
                                               sweep_policy)
                    sweep_policy.clear_protection(j)
            return freed

        def reclaim_all():
            """Last-resort pool reclaim: sweep every shard's covered
            blocks and drain the prefix cache entirely.  Returns the
            number of blocks freed — the zero-forward-progress raise
            paths fire only after this comes back empty twice."""
            held = pool.allocated()
            for s in range(max(shards, 1)):
                sweep_covered(s)
                while pcache is not None and pcache.evict_lru(s):
                    pass
            return held - pool.allocated()

        def try_ensure(j, blocks, pairs):
            """``pool.ensure`` with pool-pressure reclaim: on exhaustion,
            sweep covered blocks, then evict prefix-cache entries (LRU)
            — blocks pinned by the cache are an optimization, never an
            obligation — and retry.  Returns False when the shard
            genuinely cannot supply the blocks right now (the caller
            defers the slot and retries after the next compaction
            give-back instead of killing the whole batch).

            ``pairs`` MUST be the step's shared COW accumulator: a swap
            performed before a mid-list PoolExhausted is not re-emitted
            on retry (the fresh block is exclusively owned by then), so
            pairs recorded by failed attempts still need their payload
            copy this step — even when the slot ends up stalling."""
            while True:
                try:
                    pool.ensure(j, blocks, pairs)
                    return True
                except kv_pool.PoolExhausted:
                    s = shard_of(j)
                    if sweep_covered(s):
                        continue
                    if pcache is not None and pcache.evict_lru(s):
                        continue
                    return False

        def apply_cow(pairs):
            """Run the device block copies for this step's COW swaps
            (padded to a pow2 bucket with a repeated real pair so traced
            shapes stay bounded)."""
            nonlocal cache
            m = _pow2ceil(len(pairs))
            pad = pairs + [pairs[0]] * (m - len(pairs))
            src = jnp.asarray([p[0] for p in pad], jnp.int32)
            dst = jnp.asarray([p[1] for p in pad], jnp.int32)
            cache = self._cow(cache, src, dst)

        def ensure_row(j):
            """Re-grow the launch bucket so logical slot j has a physical
            row.  Under the current policy this never fires — shrink only
            happens after the queue drains and admissions only happen
            while it hasn't — but it guards the phys-row invariant if the
            shrink policy ever loosens."""
            if idx_of(j) >= bucket:
                resize_to(min(per_shard, _pow2ceil(idx_of(j) + 1)))

        # ---- SLO preemption / swap / brownout (runtime/scheduler.py) --
        # All of these run at clean step boundaries only (admission
        # phase, post-step pass, zero-progress backstops): mid-step a
        # victim's COW payload copies may not have been applied yet and
        # a swap-out gather would read uninitialized fresh blocks.
        # Victims are always ACTIVE (decoding) slots — an admitting slot
        # mid-prefill may hold an in-flight prefix-cache pin
        # (lookup→restore window), and interrupting it would break the
        # pin protocol; admitting slots use the existing defer machinery
        # instead.

        def victim_candidates(shard=None):
            """(priority, swap_cost_bytes, slot) for every active slot
            (optionally one shard's — blocks are shard-local, so pool
            pressure needs a same-shard victim).  Cheapest-first victim
            selection prices heterogeneous per-layer state: ring-family
            cost is the slot's mapped tail blocks (bytes), recurrent
            state adds its fixed per-slot byte price — for all-ring
            patterns this is a monotone transform of the old mapped-
            block count, so victim choices are unchanged."""
            out = []
            for j in range(n):
                if not active[j]:
                    continue
                if shard is not None and shard_of(j) != shard:
                    continue
                out.append((by_uid[slot_uid[j]].priority,
                            pool.mapped_blocks(j) * paged.block_size
                            * tail_bpt + rec_state_b, j))
            return out

        def preempt(j):
            """Swap slot ``j`` out to host memory: gather its slot
            snapshot (clustered summaries + any recurrent state — the
            recurrent family's whole checkpoint rides the same opaque
            snapshot format) + tail-ring block payloads, release its
            blocks (remembering (gid, generation) for re-adoption), park
            the request on the swap backlog.  Bit-identity on resume
            comes for free: each slot's state is a deterministic function
            of its own token stream (per-slot compaction cadence), and
            the swap round-trips that state exactly."""
            nonlocal cache
            uid = slot_uid[j]
            r = by_uid[uid]
            bt_read = pool.row_for_read(j)
            t_sw0 = time.perf_counter()
            snap, tails = self._swap_out(cache, jnp.int32(phys(j)),
                                         jnp.asarray(bt_read))
            snap, tails = jax.device_get((snap, tails))
            held = pool.release_slot(j)
            rec = SwapRecord(
                uid=uid, priority=r.priority, pos=int(pos[j]),
                cur=int(cur[j]), fed=int(fed[j]),
                since_tok=int(since_tok[j]), cov=int(cov_of(j)),
                max_new_tokens=r.max_new_tokens,
                deadline_ms=r.deadline_ms, held=held, snap=snap,
                tails=tails, epoch=self._store_epoch, seq=0,
                n_blocks_swapped=len(held), state_bytes=rec_state_b)
            slo.record_swap(rec)
            slo.swap_bytes += (len(held) * paged.block_size * tail_bpt
                               + rec_state_b)
            if tr is not None:
                t_now = time.perf_counter()
                tr.span("swap_out", t_sw0, t_now, pid=shard_of(j),
                        tid=slot_tid(j), uid=uid, blocks=len(held),
                        pos=int(pos[j]), state_bytes=rec_state_b)
                tr_close(j, t_now, "preempt")
            active[j] = False
            slot_uid[j] = -1
            since_tok[j] = 0
            return rec

        def resume_swapped(j, rec) -> bool:
            """Re-admit a parked request mid-stream into slot ``j``
            (possibly a different slot/shard than it was preempted from
            — the host payload is slot-agnostic).  Blocks that stayed
            live with an unchanged generation re-adopt without a
            re-upload; the rest re-allocate and scatter back from the
            host copy.  False = the pool cannot back it right now
            (caller defers the resume, nothing half-restored)."""
            nonlocal cache
            assert rec.epoch == self._store_epoch, (
                "swap record from another config epoch — a parked "
                "request cannot outlive the serve that preempted it")
            # headroom gate: a resume that consumes the shard's last
            # free blocks re-creates the very starvation that parked
            # requests in the first place (the freed blocks bounce
            # straight back and the engine thrashes swap-out/swap-in
            # without decoding).  Only resume when the shard can absorb
            # the re-upload AND still hand one write block to the
            # resumed slot and each surviving active slot.  The demand
            # counts only truly-fresh blocks — held blocks whose
            # (gid, gen) survived untouched re-adopt for free, so a
            # mostly-readoptable resume is not rejected for the size of
            # its whole ring.
            s = shard_of(j)
            t_r0 = time.perf_counter()
            headroom = 1 + sum(1 for jj in range(n)
                               if active[jj] and shard_of(jj) == s)
            fresh_demand = pool.resume_demand(j, rec.held)
            if pool.free_blocks(s) < fresh_demand + headroom:
                slo.deferrals += 1
                tr_brownout("defer", "resume_headroom", uid=rec.uid,
                            free=pool.free_blocks(s), fresh=fresh_demand,
                            held=len(rec.held), headroom=headroom)
                return False
            pool.free_slot(j)   # recycle any previous occupant's blocks
            readopted = []
            fresh = []
            for bi, (gid, gen) in rec.held.items():
                if pool.readopt(j, bi, gid, gen):
                    readopted.append(bi)
                else:
                    fresh.append(bi)
            if fresh and not try_ensure(j, fresh, []):
                pool.free_slot(j)       # drop the re-adoptions too
                slo.deferrals += 1
                tr_brownout("defer", "resume_alloc", uid=rec.uid,
                            fresh=len(fresh))
                return False
            slo.readopted_blocks += len(readopted)
            slo.reuploaded_blocks += len(fresh)
            ensure_row(j)
            row = np.full(pool.blocks_per_slot, pool.n_blocks, np.int32)
            for bi in fresh:
                row[bi] = pool.table[j, bi]
            snap, tails = rec.snap, rec.tails
            if self._rules is not None:
                snap = place_prefix_snapshot(snap, self._rules)
                tails = place_swap_payload(tails, self._rules)
            cache = self._swap_in(cache, snap, tails,
                                  jnp.int32(phys(j)), jnp.asarray(row))
            pos[j] = rec.pos
            cur[j] = rec.cur
            fed[j] = rec.fed
            since_tok[j] = rec.since_tok
            active[j] = True
            slot_uid[j] = rec.uid
            fr.set_frontier(j, rec.cov)
            slo.pop_record(rec)
            slo.swap_bytes -= (rec.n_blocks_swapped * paged.block_size
                               * tail_bpt + rec.state_bytes)
            if tr is not None:
                t_now = time.perf_counter()
                tr_open(j, rec.uid, t_r0, "resume", p0=rec.pos)
                tr.span("resume", t_r0, t_now, pid=shard_of(j),
                        tid=slot_tid(j), uid=rec.uid,
                        readopted=len(readopted), reuploaded=len(fresh),
                        demand=fresh_demand)
            return True

        def shed_active(j):
            """Drop an in-flight best-effort request outright (partial
            tokens already in ``toks`` are returned, blocks freed)."""
            uid = slot_uid[j]
            slo.shed_uid(uid, by_uid[uid].priority)
            if tr is not None:
                t_now = time.perf_counter()
                tr.event("shed", pid=shard_of(j), tid=slot_tid(j),
                         uid=uid, t=t_now, where="active",
                         why="brownout")
                tr_close(j, t_now, "shed")
            active[j] = False
            admitting[j] = False
            slot_uid[j] = -1
            since_tok[j] = 0
            pool.free_slot(j)

        def brownout_shed() -> bool:
            """Last brownout rung before PoolExhausted: shed best-effort
            work so the engine regains forward progress.  Cheapest
            first — a parked record (its blocks are already free), then
            the unadmittable queue head, then an active slot.  Never
            sheds the protected class: False means only high-class work
            remains and the exhaustion is real."""
            nonlocal qi
            if not slo_cfg.shed_on_exhaustion:
                return False
            rec = slo.pick_shed()
            if rec is not None:
                slo.shed_record(rec)
                slo.swap_bytes -= (rec.n_blocks_swapped
                                   * paged.block_size * tail_bpt
                                   + rec.state_bytes)
                tr_brownout("shed", "parked_record", uid=rec.uid)
                if tr is not None:
                    tr.event("shed", tid="engine", uid=rec.uid,
                             where="parked", why="pool_exhausted")
                return True
            if qi < len(order):
                r = by_uid[order[qi]]
                if not slo.is_high(r.priority):
                    slo.shed_uid(r.uid, r.priority)
                    tr_brownout("shed", "queue_head", uid=r.uid)
                    if tr is not None:
                        tr.event("shed", tid="queue", uid=r.uid,
                                 where="queue", why="pool_exhausted")
                    qi += 1
                    return True
            v = slo.pick_victim(victim_candidates(), slo_cfg.high_class)
            if v is not None:
                tr_brownout("shed", "active_victim", victim=int(v))
                shed_active(v)
                return True
            return False

        def brownout_reclaim() -> bool:
            """Zero-progress brownout: preempt the lowest-priority
            active slot when a strictly-higher-priority one needs its
            blocks (swap rung), else shed (final rung).  At zero
            forward progress ONLY, within-class preemption is allowed
            too: when every active slot is the same class and all are
            block-starved, swapping the cheapest one out lets the rest
            advance and it resumes bit-identically once capacity
            returns — strictly better than raising on all of them.
            (Needs >= 2 actives: swapping the only active would just
            resume into the same wall.)"""
            cands = victim_candidates()
            if cands and slo.can_swap():
                v = slo.pick_victim(cands, max(c[0] for c in cands))
                within_class = v is None
                if within_class and len(cands) >= 2:
                    v = slo.pick_victim(cands,
                                        max(c[0] for c in cands) + 1)
                if v is not None:
                    if tr is not None:
                        vp, vcost, _ = next(c for c in cands if c[2] == v)
                        tr_brownout("preempt", "zero_progress",
                                    victim=int(v), victim_priority=vp,
                                    victim_cost_bytes=int(vcost),
                                    within_class=within_class)
                    rec = preempt(v)
                    # hold until real tokens decode again, else the
                    # freed blocks bounce straight back (live-lock)
                    rec.hold = within_class
                    return True
            return brownout_shed()

        # per-request candidate digests, hashed once (admission steering
        # re-consults the prefix maps every engine step while a request
        # queues — only the map lookups need repeating, not the hashing).
        # The memo is keyed by uid for O(1) reuse but the prompt's
        # identity is VERIFIED before every reuse: a uid recycled for a
        # different prompt (duplicates in one stream, or uid reuse
        # against a long-lived server) must never steer or adopt with
        # the old prompt's digests.  Cluster assignment (template store)
        # happens here too — once per (uid, prompt), on first hashing.
        dig_by_uid: Dict[int, tuple] = {}
        cid_by_uid: Dict[int, int] = {}

        def prefix_digests(uid):
            po = prompts[uid]
            memo = dig_by_uid.get(uid)
            if memo is not None and (memo[0] is po or np.array_equal(
                    np.asarray(memo[0]), np.asarray(po))):
                return memo[1]
            p = np.asarray(po, np.int32)[-scfg.max_seq:]
            d = pcache.prefix_digests(p, chunk)
            dig_by_uid[uid] = (po, d)
            if store is not None:
                cid_by_uid[uid] = store.assign(p, d)
            return d

        def start_admission(j, uid) -> bool:
            nonlocal cache
            p = np.asarray(prompts[uid], np.int32)[-scfg.max_seq:]
            prompt_np[uid] = p
            if pool is not None:
                pool.free_slot(j)   # recycle the previous occupant's blocks
            if quota is not None:
                # QuotaRetention admission contract: reserve the whole
                # block budget up front — admitted ⇒ completable (nothing
                # retires mid-flight under an exact-KV policy, so a
                # mid-decode shortage could only deadlock) — and defer
                # the request back to the queue on shortage
                if not try_ensure(j, range(quota.admit_blocks(
                        len(p), by_uid[uid].max_new_tokens)), []):
                    pool.free_slot(j)
                    return False
            ensure_row(j)
            admitting[j] = True
            fed[j] = 0
            if fr is not None:
                fr.set_frontier(j, 0)
            if wr is not None:
                wr.on_slot_free(j)
            slot_uid[j] = uid
            hit = (pcache.lookup(shard_of(j), p, chunk,
                                 digests=prefix_digests(uid))
                   if pcache is not None else None)
            if hit is not None:
                # prefix-sharing fast path: adopt the registered tail
                # blocks (ref-counted; any divergent write COWs) and
                # restore the absorbed prompt centroids + coverage
                # frontier — admission resumes at fed = hit.fed instead
                # of re-streaming the shared prefix through the model
                for bi, gid in hit.blocks.items():
                    pool.adopt(j, bi, gid)
                cache = self._restore_slot_state(cache, hit.snap,
                                                 jnp.int32(phys(j)))
                fed[j] = hit.fed
                fr.set_frontier(j, hit.cov)
                # the slot now holds its own refs on every adopted
                # block — release the in-flight pin lookup() took so
                # pool-pressure eviction may reclaim the entry again
                pcache.adoption_done(hit)
            elif layout is not None or self._has_recurrent:
                # the slot's previous occupant left stale centroids and/or
                # recurrent state; ring entries are hidden by the position
                # mask, but stale counts would unmask stale centroids and
                # recurrent leaves have no mask at all — the fixed-size
                # state feeds straight into the next step (on a prefix hit
                # the restore overwrites all of this state instead)
                cache = self._reset_slot(cache, jnp.int32(phys(j)))
            if tr is not None:
                tr_open(j, uid, time.perf_counter(), "admit",
                        p0=int(fed[j]))
            return True

        def admit_blocking(j, uid) -> bool:
            nonlocal cache, pad_toks, useful_toks
            r = by_uid[uid]
            p = np.asarray(prompts[uid], np.int32)[-scfg.max_seq:]
            plen = len(p)
            cov0 = fr.target(plen) if fr is not None else 0
            if pool is not None and r.max_new_tokens > 1:
                # allocation on admission — BEFORE the prefill compute,
                # so an exhausted pool defers the request back to the
                # queue (retried after the next give-back) instead of
                # wasting a prefill or killing the batch.  Under
                # FrontierRetention only the blocks holding live
                # (uncovered) prompt positions are claimed —
                # centroid-covered offsets stay unmapped and the scatter
                # drops them; under QuotaRetention the request's whole
                # block budget is reserved (admitted ⇒ completable:
                # nothing retires mid-flight)
                pool.free_slot(j)
                # a freshly freed slot has no shared mappings, so no COW
                # pairs can arise here (blocking admission and prefix
                # sharing are mutually exclusive by validation)
                need = (range(quota.admit_blocks(plen, r.max_new_tokens))
                        if quota is not None else
                        kv_pool.live_blocks(plen, cov0, R,
                                            paged.block_size))
                if not try_ensure(j, need, []):
                    pool.free_slot(j)
                    return False
            bkt = min(scfg.max_seq,
                      -(-plen // self._bucket) * self._bucket)
            padded = np.zeros((1, bkt), np.int32)
            padded[0, :plen] = p
            t0 = time.perf_counter()
            logits1, c1 = self._prefill(jnp.asarray(padded),
                                        jnp.int32(plen - 1))
            first = int(jnp.argmax(logits1, -1)[0])
            now = time.perf_counter()
            pre_ms[uid] = (now - t0_serve) * 1e3        # TTFT
            tr_open(j, uid, t0, "admit", p0=0)
            toks[uid] = [first]
            token_t[uid] = [now]
            if tr is not None:
                tr.span("prefill", t0, now, pid=shard_of(j),
                        tid=slot_tid(j), uid=uid, prompt_len=plen)
                tr.event("first_token", pid=shard_of(j), tid=slot_tid(j),
                         uid=uid, t=now, ttft_ms=pre_ms[uid])
            pad_toks += bkt - plen
            useful_toks += plen
            if r.max_new_tokens <= 1:
                if tr is not None:
                    t_done = time.perf_counter()
                    tr.event("finish", pid=shard_of(j), tid=slot_tid(j),
                             uid=uid, t=t_done)
                    tr_close(j, t_done, "finish")
                if pool is not None:
                    pool.free_slot(j)   # done at prefill; slot stays free
                return True
            if layout is not None:
                c1 = self._clusterize(c1, cache, plen, layout)
            if self._rules is not None:
                # admission placement: kv heads shard over the model axis
                # (admission_spec) instead of the old replicate-everything
                # P() — the data-axis copy is unavoidable for a B=1 cache
                # (one device assignment per jit); the chunked admission
                # path removes the B=1 cache entirely
                c1 = place_admission(c1, self._rules)
            ensure_row(j)
            if fr is not None:
                fr.set_frontier(j, cov0)
                kv_retired["frontier"] += cov0
            if wr is not None:
                wr.on_slot_free(j)
                kv_retired["window"] += wr.advance(j, plen)
            if pool is not None:
                bt_row = jnp.asarray(pool.row_for_write(j))
                cache = self._write_slot_paged(cache, c1, jnp.int32(phys(j)),
                                               bt_row)
            else:
                cache = self._write_slot(cache, c1, jnp.int32(phys(j)))
            cur[j], pos[j] = first, plen
            active[j] = True
            since_tok[j] = 0
            slot_uid[j] = uid
            return True

        idle_retries = stall_retries = 0
        while True:
            # ---- admission ------------------------------------------------
            # next slot: the emptiest data shard's lowest free index
            # (recomputed per admission so a burst spreads across shards
            # AND keeps within-shard indices low for tight launch buckets;
            # with prefix sharing, occupancy ties prefer the shard already
            # holding the longest matching prefix entry — block ids are
            # shard-local, so reuse can't cross shards); chunked mode
            # starts at most one in-flight prefill per shard
            while True:
                # a parked (preempted) request resumes ahead of any
                # fresh admission of equal or lower priority — it
                # already paid its admission once
                rec = slo.peek_resume() if slo is not None else None
                if (rec is not None and qi < len(order)
                        and by_uid[order[qi]].priority > rec.priority):
                    rec = None
                if rec is None and qi >= len(order):
                    break
                occ = occupancy()
                if rec is not None:
                    rcands = []
                    for s in range(max(shards, 1)):
                        slots = range(s * per_shard,
                                      min((s + 1) * per_shard, n))
                        free = [j for j in slots
                                if not (active[j] or admitting[j])]
                        if free:
                            rcands.append((occ[s], s, free[0]))
                    if rcands:
                        if resume_swapped(min(rcands)[-1], rec):
                            continue
                        break   # pool-deferred resume: retry later
                    # slot pressure on a resume: preempt a strictly
                    # lower-priority active slot to make room
                    v = (slo.pick_victim(victim_candidates(),
                                         rec.priority)
                         if slo.can_swap() else None)
                    if v is not None:
                        tr_brownout("preempt", "resume_slot_pressure",
                                    victim=int(v), for_uid=rec.uid)
                        preempt(v)
                        continue
                    break
                uid = order[qi]
                p_next = (np.asarray(prompts[uid], np.int32)[-scfg.max_seq:]
                          if pcache is not None else None)
                cands = []
                for s in range(max(shards, 1)):
                    slots = range(s * per_shard, min((s + 1) * per_shard, n))
                    if chunk and any(admitting[j] for j in slots):
                        continue
                    free = [j for j in slots
                            if not (active[j] or admitting[j])]
                    if free:
                        match = (pcache.match_len(
                            s, p_next, chunk,
                            digests=prefix_digests(uid))
                                 if pcache is not None else 0)
                        # template-store steering: among equal direct
                        # matches, prefer the shard holding this
                        # request's traffic cluster — same-cluster
                        # requests land back-to-back where their
                        # entries (and pinned blocks) already live
                        aff = (store.shard_affinity(
                            s, cid_by_uid.get(uid, -1))
                               if store is not None else 0)
                        cands.append((occ[s], -match, -aff, s, free[0]))
                if not cands:
                    # slot pressure: a higher-priority head preempts
                    # the cheapest strictly-lower-priority active slot
                    # on an admissible shard (chunked mode: a shard
                    # already feeding a prefill can't admit even with a
                    # free slot, so its victims don't help)
                    if slo is not None and slo.can_swap():
                        adm = [s for s in range(max(shards, 1))
                               if not (chunk and any(
                                   admitting[j] for j in range(
                                       s * per_shard,
                                       min((s + 1) * per_shard, n))))]
                        v = slo.pick_victim(
                            [c for c in victim_candidates()
                             if shard_of(c[2]) in adm],
                            by_uid[uid].priority)
                        if v is not None:
                            tr_brownout("preempt", "slot_pressure",
                                        victim=int(v), for_uid=uid)
                            preempt(v)
                            continue
                    break
                j = min(cands)[-1]
                ok = (start_admission(j, uid) if chunk
                      else admit_blocking(j, uid))
                if ok:
                    qi += 1
                    continue
                # pool-deferred admission: count it, then walk the
                # brownout ladder — shed a best-effort request already
                # past its TTFT deadline (it can no longer meet its
                # SLO; its blocks serve requests that still can), or
                # preempt a lower-priority slot on the target shard
                if slo is not None:
                    slo.deferrals += 1
                    r = by_uid[uid]
                    if (not slo.is_high(r.priority)
                            and r.deadline_ms > 0
                            and (time.perf_counter() - t0_serve) * 1e3
                            > r.deadline_ms):
                        slo.shed_uid(uid, r.priority)
                        if tr is not None:
                            tr.event("shed", tid="queue", uid=uid,
                                     where="queue", why="deadline")
                        qi += 1
                        continue
                    if slo.can_swap():
                        v = slo.pick_victim(
                            victim_candidates(shard_of(j)), r.priority)
                        if v is not None:
                            tr_brownout("preempt", "admission_pool",
                                        victim=int(v), for_uid=uid)
                            preempt(v)
                            continue
                tr_brownout("defer", "admission_pool", uid=uid)
                break   # pool-deferred: retry after a give-back

            if not (active.any() or admitting.any()):
                if qi >= len(order) and (slo is None
                                         or slo.backlog_size() == 0):
                    break
                # admission (or a parked request's resume) deferred on
                # an idle engine: reclaim covered blocks + prefix-cache
                # pins and retry; then the brownout ladder sheds
                # best-effort work; only a genuinely unservable
                # protected request surfaces PoolExhausted
                freed = reclaim_all()
                idle_retries += 1
                if idle_retries > 1 and freed == 0:
                    if slo is not None and brownout_reclaim():
                        idle_retries = 0
                        continue
                    raise kv_pool.PoolExhausted(
                        "zero forward progress: an idle engine cannot "
                        "admit the next request even with every "
                        "reclaimable block returned — raise pool_blocks "
                        "(one slot's live window no longer fits)")
                continue
            idle_retries = 0

            # ---- bucketed launch: shrink to live occupancy ----------------
            # only once the queue has drained AND no prefill is in flight:
            # mid-stream occupancy dips are transient (a freed slot
            # readmits next step), every new physical shape costs a fresh
            # trace of the decode/compaction jits, and keeping admissions
            # at the full shape means the mixed-launch and absorb traces
            # exist at exactly one batch size.  The end-of-stream tail is
            # where shrinking pays, and its shapes ({per_shard,
            # per_shard/2, ..., 1}) are shared across serves so the
            # decode-only traces amortize
            if pool is None and qi >= len(order) and not admitting.any():
                busy_idx = [idx_of(j) for j in range(n)
                            if active[j] or admitting[j]]
                desired = min(per_shard, _pow2ceil(max(busy_idx) + 1))
                if desired < bucket:
                    resize_to(desired)
            bp = max(shards, 1) * bucket

            # ---- chunked admission: pre-step absorb (make ring room) ------
            step_chunks = {}            # logical j -> chunk len this step
            if chunk:
                for j in np.nonzero(admitting)[0]:
                    plen = len(prompt_np[slot_uid[j]])
                    cl = int(min(chunk, plen - fed[j]))
                    step_chunks[int(j)] = cl
                    if (fr is not None
                            and fed[j] + cl - fr.frontier(j) > R):
                        target = int(np.clip(
                            fed[j] + cl - R + ccfg.refresh, 0, fed[j]))
                        kv_retired["frontier"] += target - fr.frontier(j)
                        t_ab0 = time.perf_counter()
                        if pool is not None:
                            cache = self._absorb_paged(
                                cache, jnp.int32(phys(j)),
                                jnp.int32(fed[j]), jnp.int32(target),
                                jnp.asarray(pool.row_for_read(j)))
                            fr.set_frontier(int(j), target)
                            pool.free_retired(int(j), int(fed[j]), fr)
                        else:
                            cache = self._absorb(cache, jnp.int32(phys(j)),
                                                 jnp.int32(fed[j]),
                                                 jnp.int32(target))
                            fr.set_frontier(int(j), target)
                        n_absorbs += 1
                        if tr is not None:
                            tr.span("absorb", t_ab0, time.perf_counter(),
                                    pid=shard_of(int(j)),
                                    tid=slot_tid(int(j)),
                                    uid=slot_uid[int(j)],
                                    target=int(target))

            # ---- build the launch -----------------------------------------
            mixed = bool(step_chunks)
            width = chunk if mixed else 1
            real_rows = int(active.sum()) + sum(step_chunks.values())
            stalled_decode = set()
            stalled_admit = set()
            if pool is not None:
                # paged packed launch: one row per real (slot, position)
                # pair, padded per data shard to a power-of-two row bucket
                # (bounded trace count) — compute ∝ real tokens instead of
                # slots × width.  Blocks this step's ring writes land in
                # are made WRITABLE first: unmapped blocks allocate (or
                # re-allocate after a give-back) and shared blocks
                # copy-on-write swap (prefix sharing) — the payload copies
                # run on device before any ring write.  A slot whose shard
                # cannot supply its blocks even after reclaim stalls for
                # the step (its row is simply not packed) and retries
                # after the next give-back, instead of killing the batch.
                # one shared accumulator: COW swaps performed before a
                # mid-list exhaustion (or by a slot that then stalls)
                # still get their payload copies below — the table
                # already points at the fresh blocks
                cow_pairs = []
                for j in range(n):
                    if admitting[j] and j in step_chunks:
                        if not try_ensure(j, kv_pool.write_blocks(
                                int(fed[j]), step_chunks[j], R,
                                paged.block_size), cow_pairs):
                            del step_chunks[j]
                            stalled_admit.add(j)
                    elif active[j]:
                        if not try_ensure(j, kv_pool.write_blocks(
                                int(pos[j]), 1, R, paged.block_size),
                                cow_pairs):
                            stalled_decode.add(j)
                if cow_pairs:
                    apply_cow(cow_pairs)
                mixed = bool(step_chunks)
                width = chunk if mixed else 1
                real_rows = (int(active.sum()) - len(stalled_decode)
                             + sum(step_chunks.values()))
                if real_rows > 0 and slo is not None:
                    # forward progress this step: records parked by a
                    # zero-progress preemption become resumable again
                    slo.clear_holds()
                if real_rows == 0:
                    # every slot is pool-stalled: nothing can advance
                    # until blocks come back, and nothing is running to
                    # give them back — reclaim; if that yields nothing
                    # twice, no forward progress is possible
                    freed = reclaim_all()
                    stall_retries += 1
                    if stall_retries > 1 and freed == 0:
                        # brownout ahead of the raise: swap out the
                        # lowest-priority stalled slot so its blocks
                        # unstick higher ones, else shed best-effort
                        if slo is not None and brownout_reclaim():
                            stall_retries = 0
                            continue
                        raise kv_pool.PoolExhausted(
                            "zero forward progress: every slot's next "
                            "ring write needs a block and no block is "
                            "reclaimable — raise pool_blocks or shorten "
                            "refresh_every")
                    continue
                stall_retries = 0
                rows_by_shard = [[] for _ in range(max(shards, 1))]
                for j in range(n):
                    s = shard_of(j)
                    if admitting[j] and j in step_chunks:
                        cl = step_chunks[j]
                        p = prompt_np[slot_uid[j]]
                        for i in range(cl):
                            rows_by_shard[s].append(
                                (j, int(p[fed[j] + i]), int(fed[j]) + i,
                                 int(fed[j]) + cl, i))
                    elif active[j] and j not in stalled_decode:
                        rows_by_shard[s].append(
                            (j, int(cur[j]), int(pos[j]), int(pos[j]) + 1,
                             0))
                row_bucket = _pow2ceil(
                    max(max(len(rs) for rs in rows_by_shard), 1))
                np_rows = max(shards, 1) * row_bucket
                tokp = np.zeros(np_rows, np.int32)
                rslot = np.zeros(np_rows, np.int32)
                rpos = np.full(np_rows, -1, np.int32)
                rtw = np.zeros(np_rows, np.int32)
                # each row's index within its admission chunk (decode and
                # padding rows 0) — sequences sliding-window ring commits
                # in the 'L' sublayer's width-step loop
                rcidx = np.zeros(np_rows, np.int32)
                last_row: Dict[int, int] = {}
                for s, rs in enumerate(rows_by_shard):
                    base = s * row_bucket
                    # padding rows reference a real slot of their own
                    # shard (the shard's phys base) so the kernel's
                    # gathers stay shard-local; their qpos1 of 0 masks
                    # everything
                    rslot[base:base + row_bucket] = s * bucket
                    for i, (j, tk, p_, tw_, ci) in enumerate(rs):
                        tokp[base + i] = tk
                        rslot[base + i] = phys(j)
                        rpos[base + i] = p_
                        rtw[base + i] = tw_
                        rcidx[base + i] = ci
                        last_row[j] = base + i
                bt_dev = bt_device()
                t0 = time.perf_counter()
                with _annot("decode_packed"):
                    logits, cache = self._decode_packed(
                        cache, jnp.asarray(tokp), jnp.asarray(rslot),
                        jnp.asarray(rpos), jnp.asarray(rtw),
                        jnp.asarray(rcidx), bt_dev, width)
                nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
                nxt_of = lambda jj: nxt[last_row[jj]]      # noqa: E731
                # launch_rows_frac / launch_bucket_mean stay SLOT
                # bookkeeping (the slot axis never shrinks in paged
                # mode); the packed-row picture lives in launch_pad_frac
                # / launch_ragged_frac via compute_rows
                rows_step, compute_rows = bp, np_rows
            else:
                tok = np.zeros((bp, width), np.int32)
                t_vec = np.zeros(bp, np.int32)
                cl_vec = np.ones(bp, np.int32)
                for j in range(n):
                    if idx_of(j) >= bucket:
                        continue
                    pj = phys(j)
                    if admitting[j]:
                        cl = step_chunks[j]
                        p = prompt_np[slot_uid[j]]
                        tok[pj, :cl] = p[fed[j]:fed[j] + cl]
                        t_vec[pj] = fed[j]
                        cl_vec[pj] = cl
                    else:
                        tok[pj, 0] = cur[j]
                        t_vec[pj] = pos[j]

                t0 = time.perf_counter()
                if mixed:
                    with _annot("mixed_step"):
                        logits, cache = self._mixed(cache,
                                                    jnp.asarray(tok),
                                                    jnp.asarray(t_vec),
                                                    jnp.asarray(cl_vec))
                else:
                    with _annot("decode_step"):
                        logits, cache = self._decode(cache,
                                                     jnp.asarray(tok),
                                                     jnp.asarray(t_vec))
                nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
                nxt_of = lambda jj: nxt[phys(jj)]          # noqa: E731
                rows_step, compute_rows = bp, bp * width
            now = time.perf_counter()
            dec_s += now - t0
            decode_steps += 1
            rows_launched += rows_step
            launch_real += real_rows
            launch_padded += compute_rows
            wasted_slots += int(n - (active | admitting).sum())
            if tr is not None:
                kind = ("decode" if not step_chunks else
                        ("mixed" if real_rows > sum(step_chunks.values())
                         else "prefill"))
                tr.span("engine_step", t0, now, tid="engine", kind=kind,
                        width=int(width), rows=int(compute_rows),
                        real_rows=int(real_rows),
                        occupancy=[int(x) for x in occupancy()],
                        pool_free=([pool.free_blocks(s)
                                    for s in range(max(shards, 1))]
                                   if pool is not None else []),
                        pool_live=(int(pool.allocated())
                                   if pool is not None else 0),
                        stalled=len(stalled_decode) + len(stalled_admit))
            advanced = active.copy()
            for j in stalled_decode:
                advanced[j] = False     # a pool-stalled slot didn't decode
            since_tok[advanced] += 1
            n_chunks += len(step_chunks)
            if shards > 1:
                shard_steps += 1
                for j in range(n):
                    if active[j] or admitting[j]:
                        shard_busy_steps[shard_of(j)] += 1
            if layout is not None:
                live = 0
                for j in range(n):
                    if admitting[j]:
                        live += min(int(fed[j]) + step_chunks.get(int(j), 0)
                                    - cov_of(j), R)
                    elif active[j]:
                        live += min(int(pos[j]) + 1 - cov_of(j), R)
                # physical blocks only: a block mapped by several slots
                # (prefix sharing) counts once — the duplicate-mapping
                # surplus is tracked separately as the sharing saving
                alloc = (pool.allocated() * paged.block_size if pool
                         else bp * R)
                kv_live_sum += live
                kv_alloc_sum += alloc
                kv_alloc_peak = max(kv_alloc_peak, alloc)
                if pool is not None:
                    kv_shared_peak = max(kv_shared_peak,
                                         pool.shared_extra())

            # ---- host update ---------------------------------------------
            for j in range(n):
                if idx_of(j) >= bucket:
                    continue
                pj = phys(j)
                uid = slot_uid[j]
                if admitting[j]:
                    if j not in step_chunks:
                        continue        # pool-stalled this step
                    cl = step_chunks[j]
                    fed[j] += cl
                    if tr is not None:
                        tr.event("prefill_chunk", pid=shard_of(j),
                                 tid=slot_tid(j), uid=uid, t=now,
                                 fed=int(fed[j]), chunk=cl)
                    if wr is not None:
                        kv_retired["window"] += wr.advance(j, int(fed[j]))
                    plen = len(prompt_np[uid])
                    useful_toks += cl
                    if fed[j] < plen:
                        # chunk-boundary state is prefix-pure — a
                        # deterministic function of tokens[:fed] alone
                        # (per-slot compaction gating keeps neighbours
                        # from perturbing it) — so register it for
                        # later same-prefix admissions
                        if (pcache is not None and fed[j] % chunk == 0
                                and fed[j] >= max(self._pshare.min_prefix,
                                                  chunk)):
                            blocks = {
                                bi: int(pool.table[j, bi])
                                for bi in kv_pool.live_blocks(
                                    int(fed[j]), cov_of(j), R,
                                    paged.block_size)
                                if pool.table[j, bi] >= 0}
                            snap = self._snap_slot(cache, jnp.int32(pj))
                            if self._rules is not None:
                                snap = place_prefix_snapshot(
                                    snap, self._rules)
                            pcache.register(shard_of(j), prompt_np[uid],
                                            int(fed[j]), cov_of(j),
                                            blocks, snap,
                                            cluster=cid_by_uid.get(
                                                uid, -1))
                        continue
                    # final chunk landed: its last row's logits are the
                    # request's first generated token
                    if fr is not None:
                        target_end = fr.target(plen)
                        if fr.frontier(j) < target_end:
                            kv_retired["frontier"] += (target_end
                                                       - fr.frontier(j))
                            t_ab0 = time.perf_counter()
                            if pool is not None:
                                cache = self._absorb_paged(
                                    cache, jnp.int32(pj), jnp.int32(plen),
                                    jnp.int32(target_end),
                                    jnp.asarray(pool.row_for_read(j)))
                                fr.set_frontier(j, target_end)
                                pool.free_retired(j, plen, fr)
                            else:
                                cache = self._absorb(cache, jnp.int32(pj),
                                                     jnp.int32(plen),
                                                     jnp.int32(target_end))
                                fr.set_frontier(j, target_end)
                            n_absorbs += 1
                            if tr is not None:
                                tr.span("absorb", t_ab0,
                                        time.perf_counter(),
                                        pid=shard_of(j), tid=slot_tid(j),
                                        uid=uid, target=int(target_end))
                    first = int(nxt_of(j))
                    toks[uid] = [first]
                    token_t[uid] = [now]
                    pre_ms[uid] = (now - t0_serve) * 1e3    # TTFT
                    if tr is not None:
                        tr.event("first_token", pid=shard_of(j),
                                 tid=slot_tid(j), uid=uid, t=now,
                                 ttft_ms=pre_ms[uid])
                    admitting[j] = False
                    if by_uid[uid].max_new_tokens <= 1:
                        if tr is not None:
                            tr.event("finish", pid=shard_of(j),
                                     tid=slot_tid(j), uid=uid, t=now)
                            tr_close(j, now, "finish")
                        slot_uid[j] = -1
                        if pool is not None:
                            if quota is not None:
                                kv_retired["quota"] += (
                                    int((pool.table[j] >= 0).sum())
                                    * paged.block_size)
                            pool.free_slot(j)   # recycling on early exit
                    else:
                        active[j] = True
                        since_tok[j] = 0
                        pos[j] = plen
                        cur[j] = first
                elif active[j] and j not in stalled_decode:
                    toks[uid].append(int(nxt_of(j)))
                    token_t[uid].append(now)
                    pos[j] += 1
                    if wr is not None:
                        kv_retired["window"] += wr.advance(j, int(pos[j]))
                    cur[j] = nxt_of(j)
                    if len(toks[uid]) >= by_uid[uid].max_new_tokens:
                        active[j] = False
                        since_tok[j] = 0
                        if tr is not None:
                            tr.event("finish", pid=shard_of(j),
                                     tid=slot_tid(j), uid=uid, t=now)
                            tr_close(j, now, "finish")
                        if pool is not None:
                            if quota is not None:
                                # an exact-KV slot retires its whole
                                # footprint in one go at request exit
                                kv_retired["quota"] += (
                                    int((pool.table[j] >= 0).sum())
                                    * paged.block_size)
                            pool.free_slot(j)   # recycling on early exit

            # ---- compaction: per-slot cadence -----------------------------
            # a slot is due after ``refresh`` of its OWN decode tokens;
            # one batched call refreshes every due slot (others pass
            # length 0 and recompact_clustered's per-slot gate keeps
            # their summaries bit-identical).  Per-slot triggering —
            # rather than the old global since_tok reset — makes each
            # slot's compaction schedule a function of its own stream
            # alone, so admission timing (bursts, prefix-shared fast
            # paths, pool stalls) can never shift a neighbour's
            # compaction points and change its tokens
            due = [j for j in range(n)
                   if ccfg is not None and active[j]
                   and since_tok[j] >= ccfg.refresh and idx_of(j) < bucket]
            if due:
                lengths = np.zeros(bp, np.int32)
                for j in due:
                    lengths[phys(j)] = pos[j]
                t_c0 = time.perf_counter()
                if pool is not None:
                    cache = self._compact_paged(cache, jnp.asarray(lengths),
                                                bt_device())
                else:
                    cache = self.compact_kv(cache, lengths, ccfg)
                    if self._rules is not None:
                        # eviction/compaction rebuilt the clustered leaves
                        # outside the constrained decode jit — put them
                        # back on their mesh layout before the next step
                        cache = shard_cache(cache, self._rules)
                # host frontier mirror (recompact_clustered's formula) —
                # compaction is when the paged engine returns retired
                # blocks to the pool
                for j in due:
                    newc = max(fr.frontier(j), fr.target(int(pos[j])))
                    kv_retired["frontier"] += newc - fr.frontier(j)
                    fr.set_frontier(j, newc)
                    if pool is not None:
                        pool.free_retired(j, int(pos[j]), fr)
                    since_tok[j] = 0
                n_compacts += 1
                if tr is not None:
                    tr.span("compact", t_c0, time.perf_counter(),
                            tid="engine", slots=[int(j) for j in due])

            # ---- post-step priority pass -----------------------------
            # a pool-stalled slot (decode or admission) whose priority
            # strictly exceeds a neighbour's gets that neighbour's
            # blocks next step: swap the shard's cheapest lower-priority
            # active slot out (one victim per shard per step — a clean
            # boundary, every COW copy of this step already applied)
            if slo is not None and (stalled_decode or stalled_admit):
                for s in range(max(shards, 1)):
                    sp = [by_uid[slot_uid[j]].priority
                          for j in (stalled_decode | stalled_admit)
                          if shard_of(j) == s]
                    if not sp or not slo.can_swap():
                        continue
                    v = slo.pick_victim(victim_candidates(s), max(sp))
                    if v is not None:
                        preempt(v)

        if pcache is not None:
            if store is None:
                # entries are a per-serve cache: release every pinned
                # block so the pool drains to zero with the request
                # stream
                pcache.clear()
            else:
                # persistent template store: entries and their pinned
                # blocks survive the drain — the pool and the device
                # cache park on the store (epoch-keyed, so any Server
                # under the same epoch can adopt) with mirror attrs on
                # the server for introspection.  Drain accounting
                # weakens from allocated()==0 to
                # allocated()==pinned_blocks(); anything beyond the
                # pins is a leak and shows up in pool_blocks_end.
                self._tmpl_pool, self._tmpl_cache = pool, cache
                pcache.parked = (pool, cache, self._store_epoch,
                                 max(shards, 1))
        wall = time.perf_counter() - t0_serve
        gen_total = sum(len(v) for v in toks.values())
        # each request's first token comes from prefill; tokens/s rates
        # only the tokens the decode loop actually produced
        dec_tokens = gen_total - len(toks)
        dec_ms_tok = dec_s * 1e3 / max(gen_total, 1)
        ttfts = [pre_ms[u] / 1e3 for u in pre_ms]
        itls: List[float] = []
        for ts in token_t.values():
            itls.extend(b - a for a, b in zip(ts, ts[1:]))
        # ---- publish into the typed metrics registry -----------------
        # last_stats is regenerated from the registry (flat_view) so
        # every historical key keeps working while the keys themselves
        # become typed, documented metrics (see reg.reference_table())
        reg.counter("decode_steps",
                    "engine launches this serve").add(decode_steps)
        reg.gauge("slot_waste", "idle slot-steps / total slot-steps"
                  ).set(wasted_slots / max(decode_steps * n, 1))
        reg.gauge("prefill_pad_frac",
                  "prompt pad tokens / all prefill tokens"
                  ).set(pad_toks / max(pad_toks + useful_toks, 1))
        reg.counter("gen_tokens", "tokens generated this serve"
                    ).add(gen_total)
        reg.gauge("decode_s", "seconds inside engine launches"
                  ).set(dec_s)
        reg.gauge("tokens_per_s", "decode-loop tokens per launch second"
                  ).set(dec_tokens / max(dec_s, 1e-9))
        reg.gauge("wall_s", "end-to-end serve wall seconds").set(wall)
        reg.gauge("tokens_per_s_wall", "all tokens per wall second"
                  ).set(gen_total / max(wall, 1e-9))
        ht = reg.histogram("ttft", "wall-clock time to first token",
                           quantiles=(50, 95, 99), scale=1e3,
                           suffix="_ms")
        for v in ttfts:
            ht.observe(v)
        hi = reg.histogram("itl", "inter-token latency",
                           quantiles=(50, 95, 99), scale=1e3,
                           suffix="_ms")
        for v in itls:
            hi.observe(v)
        reg.gauge("launch_rows_frac", "launched slot rows / slots×steps"
                  ).set(rows_launched / max(decode_steps * n, 1))
        reg.gauge("launch_bucket_mean", "mean launch bucket per shard"
                  ).set(rows_launched
                        / max(decode_steps * max(shards, 1), 1))
        # padded-compute waste: launched rows × width that carried no
        # real (slot, position) pair — the number the packed ragged
        # launch exists to shrink — and its complement, the fraction
        # of launched compute rows that were real tokens
        reg.gauge("launch_pad_frac",
                  "launched compute rows carrying no real token"
                  ).set(1.0 - launch_real / max(launch_padded, 1))
        reg.gauge("launch_ragged_frac",
                  "real tokens / launched compute rows"
                  ).set(launch_real / max(launch_padded, 1))
        reg.counter("prefill_chunks",
                    "prompt chunks fed through mixed launches"
                    ).add(n_chunks)
        reg.counter("kv_absorbs", "streaming absorb_chunk calls"
                    ).add(n_absorbs)
        reg.counter("kv_compactions", "batched compaction passes"
                    ).add(n_compacts)
        # positions each retention policy retired this serve —
        # FrontierRetention counts coverage-frontier advancement
        # (absorbs + compactions + admission clusterize, dense and
        # paged alike), WindowRetention positions that aged out of
        # 'L' layers' sliding windows, QuotaRetention block-backed
        # positions released at request exit.  Always present so
        # benchmark schemas stay stable across engine modes
        reg.counter("kv_retired_frontier",
                    "positions retired behind the coverage frontier"
                    ).add(kv_retired["frontier"])
        reg.counter("kv_retired_window",
                    "positions aged out of sliding windows"
                    ).add(kv_retired["window"])
        reg.counter("kv_retired_quota",
                    "block-backed positions released at request exit"
                    ).add(kv_retired["quota"])
        # recurrent family: the retirement counter is identically zero
        # by construction (fixed-size state folds every position) — the
        # explicit key comes from RecurrentRetention.diagnostics so the
        # invariant is published, not silently omitted
        reg.counter("kv_retired_recurrent",
                    "positions retired from recurrent state (0 by "
                    "construction: fixed-size state folds every position)"
                    ).add(rr.diagnostics()["kv_retired_recurrent"]
                          if rr is not None else 0)
        # per-family state-byte picture (core/layer_state.py): dense
        # per-slot bytes each family carries — ring centroid summaries /
        # window rings (pool-backed tail blocks are priced separately in
        # the kv_bytes_* metrics) vs the recurrent family's fixed-size
        # whole-state price.  Always present so benchmark schemas stay
        # stable across layer patterns
        reg.gauge("state_bytes_ring",
                  "dense ring-family state bytes per slot (tails excluded)"
                  ).set(float(layer_state.ring_state_bytes(
                      cache, max(shards, 1) * bucket)))
        reg.gauge("state_bytes_recurrent",
                  "recurrent-family state bytes per slot"
                  ).set(float(rec_state_b))
        if layout is not None:
            # KV-allocation picture, comparable across paged and dense:
            # dense "allocates" every launched slot's full tail ring
            reg.gauge("kv_frag",
                      "1 - live ring tokens / allocated ring capacity"
                      ).set(1.0 - kv_live_sum / max(kv_alloc_sum, 1))
            reg.gauge("kv_alloc_tokens_peak",
                      "peak allocated ring tokens"
                      ).set(float(kv_alloc_peak))
            if pool is not None:
                # physical blocks only: shared blocks count once
                # (kv_shared_blocks/kv_bytes_saved carry the surplus);
                # alloc/free/retain/cow are per-serve deltas vs the
                # serve-start mark (a persistent pool carries lifetime
                # counters)
                pool.publish(reg, pool_mark,
                             paged.block_size * tail_bpt)
                reg.gauge("kv_shared_blocks",
                          "peak logical mappings beyond physical blocks"
                          ).set(float(kv_shared_peak))
                reg.gauge("kv_bytes_saved",
                          "tail KV bytes prefix sharing avoided"
                          ).set(float(kv_shared_peak * paged.block_size
                                      * tail_bpt))
                # every request completed → every block recycled, minus
                # what the template store deliberately pins across
                # serves (0 = no leak in both modes)
                reg.gauge("pool_blocks_end",
                          "blocks live beyond store pins (>0 = leak)"
                          ).set(float(pool.allocated()
                                      - (store.pinned_blocks()
                                         if store is not None else 0)))
                if pcache is not None:
                    # per-serve deltas (the counters are lifetime-
                    # cumulative on the cache object; raw totals would
                    # double-count every serve after the first)
                    reg.counter("prefix_hits",
                                "prefix-cache adoptions this serve"
                                ).add(pcache.hits - hits0)
                    reg.counter("prefix_tokens_reused",
                                "prompt tokens adopted this serve"
                                ).add(pcache.tokens_reused - reused0)
                if store is not None:
                    # lifetime store view (persist=True counters survive
                    # begin_serve) + per-cluster traffic picture
                    store.publish(reg, paged.block_size * tail_bpt)
            else:
                reg.gauge("kv_bytes_peak_per_shard",
                          "peak live tail-KV bytes on the busiest shard"
                          ).set(float(per_shard * R * tail_bpt))
                reg.gauge("pool_occupancy_peak",
                          "peak live blocks / capacity").set(1.0)
        if slo is not None:
            # brownout ladder accounting (sched_shed_high must be 0:
            # the protected class is never shed, only raised on)
            slo.publish(reg)
        if shards > 1:
            reg.gauge("n_data_shards", "data shards this serve"
                      ).set(float(shards))
            for s in range(shards):
                reg.gauge(f"slot_waste_shard{s}",
                          f"idle slot-step fraction on data shard {s}"
                          ).set(1.0 - shard_busy_steps[s]
                                / (shard_steps * per_shard)
                                if shard_steps else 0.0)
        self.last_stats = reg.flat_view()
        if tr is not None:
            self.last_trace = tr.finish()
        shed_uids = slo.shed_uids if slo is not None else ()
        return [Completion(uid=r.uid, tokens=toks.get(r.uid, []),
                           prefill_ms=pre_ms.get(r.uid, 0.0),
                           decode_ms=dec_ms_tok
                           * len(toks.get(r.uid, [])),
                           shed=r.uid in shed_uids)
                for r in requests]

    @staticmethod
    def _params_digest(params) -> str:
        """Content hash of the parameter pytree: leaf paths, shapes,
        dtypes, and raw bytes.  The template-store epoch stamps this
        instead of ``id(params)`` so reloaded identical weights (a new
        pytree object, same bytes) keep a warm store, while any real
        weight change still invalidates every snapshot."""
        h = hashlib.blake2b(digest_size=16)
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for kp, leaf in flat:
            arr = np.asarray(leaf)
            h.update("/".join(_key_name(k) for k in kp).encode())
            h.update(repr((arr.shape, str(arr.dtype))).encode())
            h.update(arr.tobytes())
        return h.hexdigest()

    @staticmethod
    def _tail_bytes_per_token(cache) -> int:
        """Bytes one ring position costs across every tail leaf of the
        stack (k+v, all layers) — same accounting for the dense per-slot
        ring and the paged block pool, so their peak-KV stats compare."""
        total = 0
        flat, _ = jax.tree_util.tree_flatten_with_path(cache)
        for kp, leaf in flat:
            if _key_name(kp[-1]) not in ("k_tail", "v_tail"):
                continue
            stacked = _key_name(kp[0]) == "scan"
            h, dh = leaf.shape[-2], leaf.shape[-1]
            lyr = leaf.shape[0] if stacked else 1
            total += lyr * h * dh * leaf.dtype.itemsize
        return total

    # ------------------------------------------------------------------
    # bucketed launches: slot-axis resize
    # ------------------------------------------------------------------

    def _resize_cache(self, cache, ob: int, nb: int):
        """Resize every cache leaf's slot axis from shards*ob to
        shards*nb physical rows (jitted per (ob, nb) pair, donated).
        Dead high slots hold no live request state, so shrink drops them
        and grow zero-fills."""
        fn = self._resize_jits.get((ob, nb))
        if fn is None:
            shards = max(self._n_data_shards, 1)

            def impl(c):
                flat, treedef = jax.tree_util.tree_flatten_with_path(c)
                out = []
                for kp, leaf in flat:
                    name = _key_name(kp[-1])
                    if name in ("k_scale", "v_scale"):  # per-head, no slots
                        out.append(leaf)
                        continue
                    axis = 1 if _key_name(kp[0]) == "scan" else 0
                    out.append(_slot_resize(leaf, axis, shards, ob, nb))
                res = jax.tree_util.tree_unflatten(treedef, out)
                return self._constrain(res)

            fn = jax.jit(impl, donate_argnums=(0,))
            self._resize_jits[(ob, nb)] = fn
        return fn(cache)

    # ------------------------------------------------------------------
    # chunked admission: slot reset + streaming absorb
    # ------------------------------------------------------------------

    def _reset_slot_impl(self, cache, j):
        """Zero one slot's clustered bookkeeping (counts + cov) and its
        recurrent state ahead of a fresh chunked admission.  Ring/centroid
        payloads need no wipe: ring entries are hidden by the position
        mask until the chunk stream overwrites them, and zero-count
        centroids are masked.  Recurrent leaves have no mask — the whole
        fixed-size state IS live input to the next step — so the previous
        occupant's (conv, ssm) / (conv, h) must be zeroed outright."""
        def walk(node):
            if _is_clustered_kv(node):
                out = dict(node)
                if node["k_cents"].ndim == 5:            # scan-stacked
                    out["counts"] = node["counts"].at[:, j].set(0.0)
                    out["cov"] = node["cov"].at[:, j].set(0)
                else:
                    out["counts"] = node["counts"].at[j].set(0.0)
                    out["cov"] = node["cov"].at[j].set(0)
                return out
            if layer_state.is_recurrent_leaf(node):
                if layer_state.recurrent_leaf_stacked(node):
                    return {k: v.at[:, j].set(0) for k, v in node.items()}
                return {k: v.at[j].set(0) for k, v in node.items()}
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(cache)

    def _absorb_impl(self, cache, j, lengths, target, ccfg):
        """Advance slot j's coverage frontier to ``target`` by folding its
        aged ring entries into centroids (kv_compress.absorb_chunk),
        touching only that slot — mid-decode neighbours must stay
        bit-identical.  ``lengths`` = ring positions written so far."""
        def leaf(node):
            stacked = node["k_cents"].ndim == 5
            ax = 1 if stacked else 0
            sub = {k: jax.lax.dynamic_slice_in_dim(v, j, 1, axis=ax)
                   for k, v in node.items()}
            if stacked:
                lyr = node["k_cents"].shape[0]
                flat = {k: v.reshape((lyr,) + v.shape[2:])
                        for k, v in sub.items()}
                got = kv_compress.absorb_chunk(
                    flat, jnp.full((lyr,), lengths, jnp.int32),
                    jnp.full((lyr,), target, jnp.int32), ccfg)
                got = {k: v[:, None] for k, v in got.items()}
            else:
                got = kv_compress.absorb_chunk(
                    sub, jnp.full((1,), lengths, jnp.int32),
                    jnp.full((1,), target, jnp.int32), ccfg)
            return {k: jax.lax.dynamic_update_slice_in_dim(
                node[k], got[k].astype(node[k].dtype), j, axis=ax)
                for k in node}

        def walk(node):
            if _is_clustered_kv(node):
                return leaf(node)
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(cache)

    # ------------------------------------------------------------------
    # paged path: pool gathers/scatters around the same compaction math
    #
    # Every paged op gathers a slot's tail blocks into the dense ring
    # layout, runs the UNCHANGED kv_compress routine, and writes back
    # only centroids/counts/cov (compaction never rewrites tail bytes).
    # Offsets whose blocks are unmapped read garbage from the sanitized
    # alias block — they are strictly outside [cov, t), so they carry
    # weight 0 in the clustering and are masked in attention, and the
    # results stay bit-identical to the dense engine.
    # ------------------------------------------------------------------

    @staticmethod
    def _gather_tail_rows(pool_arr, bt):
        """Dense ring view of a paged tail pool.  pool (nb, bs, H, Dh) +
        bt (..., T) → (..., T*bs, H, Dh); stacked pool (L, nb, bs, H, Dh)
        → (L, ..., T*bs, H, Dh)."""
        stacked = pool_arr.ndim == 5
        h, dh = pool_arr.shape[-2], pool_arr.shape[-1]
        if stacked:
            lyr = pool_arr.shape[0]
            got = pool_arr[:, bt]          # (L, ..., T, bs, H, Dh)
            return got.reshape((lyr,) + bt.shape[:-1] + (-1, h, dh))
        got = pool_arr[bt]                 # (..., T, bs, H, Dh)
        return got.reshape(bt.shape[:-1] + (-1, h, dh))

    def _write_slot_paged_impl(self, dst, src, j, bt_row, blk: int):
        """Paged twin of ``_write_slot_impl``: clustered leaves write
        centroids/counts/cov densely at slot j and scatter the B=1 dense
        tail ring into the slot's freshly-allocated pool blocks
        (``bt_row`` (T,), unmapped = covered offsets pointing out of
        range so mode='drop' skips them); all other leaves take the
        dense slot write."""
        def upd(axis):
            def f(d, s):
                idx = (0,) * axis + (j,) + (0,) * (d.ndim - axis - 1)
                return jax.lax.dynamic_update_slice(d, s.astype(d.dtype),
                                                    idx)
            return f

        def leaf(dnode, snode, axis):
            out = {}
            for key in ("k_cents", "v_cents", "counts", "cov"):
                out[key] = upd(axis)(dnode[key], snode[key])
            for key in ("k_tail", "v_tail"):
                pool_arr, srct = dnode[key], snode[key]
                if axis == 1:              # scan-stacked: src (L, 1, R, …)
                    lyr = srct.shape[0]
                    blocks = srct.reshape(lyr, -1, blk, srct.shape[-2],
                                          srct.shape[-1])
                    out[key] = pool_arr.at[:, bt_row].set(
                        blocks.astype(pool_arr.dtype), mode="drop")
                else:                      # src (1, R, H, Dh)
                    blocks = srct.reshape(-1, blk, srct.shape[-2],
                                          srct.shape[-1])
                    out[key] = pool_arr.at[bt_row].set(
                        blocks.astype(pool_arr.dtype), mode="drop")
            return out

        def walk(dnode, snode, axis):
            if _is_clustered_kv(dnode):
                return leaf(dnode, snode, axis)
            if isinstance(dnode, dict):
                return {k: walk(dnode[k], snode[k], axis) for k in dnode}
            if isinstance(dnode, list):
                return [walk(d, s, axis) for d, s in zip(dnode, snode)]
            return upd(axis)(dnode, snode)

        out = dict(dst)
        for key in ("prefix", "tail"):
            out[key] = [walk(dc, sc, 0) for dc, sc in zip(dst[key],
                                                          src[key])]
        if "scan" in dst:
            out["scan"] = walk(dst["scan"], src["scan"], 1)
        return out

    @staticmethod
    def _gather_swap_tails(cache, bt_row):
        """Swap-out gather: every clustered leaf's tail blocks for one
        slot, in ring-block order.  ``bt_row`` is the slot's (T,)
        read-sanitized table row (unmapped → shard base: those rows
        gather alias garbage the cov/position masks already exclude, and
        swap-in never scatters them back).  Non-clustered nodes yield
        None — the swap protocol, like the prefix snapshot it extends,
        is defined only for FrontierRetention (clustered) state."""
        def leaf(node):
            out = {}
            for key in ("k_tail", "v_tail"):
                p = node[key]
                if p.ndim == 5:            # scan-stacked (L, nb, bs, H, Dh)
                    out[key] = p[:, bt_row]
                else:                      # (nb, bs, H, Dh)
                    out[key] = p[bt_row]
            return out

        def walk(node):
            if _is_clustered_kv(node):
                return leaf(node)
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return None

        return walk(cache)

    @staticmethod
    def _scatter_swap_tails(cache, tails, bt_row):
        """Swap-in scatter: write a resuming slot's host tail payloads
        into its freshly-allocated pool blocks.  ``bt_row`` is (T,) with
        ONLY fresh allocations holding real ids — re-adopted blocks and
        never-mapped ring blocks point out of range (``n_blocks``) so
        mode='drop' skips them: a re-adopted block may be shared
        (ref > 1) and its device bytes provably equal the host copy
        already, so writing it would violate the COW protocol for zero
        information."""
        def leaf(node, tl):
            out = dict(node)
            for key in ("k_tail", "v_tail"):
                p = node[key]
                if p.ndim == 5:
                    out[key] = p.at[:, bt_row].set(
                        tl[key].astype(p.dtype), mode="drop")
                else:
                    out[key] = p.at[bt_row].set(
                        tl[key].astype(p.dtype), mode="drop")
            return out

        def walk(node, tl):
            if _is_clustered_kv(node):
                return leaf(node, tl)
            if isinstance(node, dict):
                return {k: walk(v, tl[k]) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v, t2) for v, t2 in zip(node, tl)]
            return node

        return walk(cache, tails)

    def _cow_impl(self, cache, src, dst):
        """Device half of copy-on-write (prefix sharing): copy pool
        blocks ``src`` → ``dst`` ((m,) global ids, same shard per pair)
        in every clustered tail leaf.  The allocator already swapped the
        writing slot's table entry to ``dst`` (kv_pool.ensure), so this
        copy must land before the step's ring writes — the engine threads
        the cache through this jit first.  Padding pairs repeat a real
        pair; the duplicate scatter writes identical values, so the
        result is deterministic."""
        def leaf(node):
            out = dict(node)
            for key in ("k_tail", "v_tail"):
                p = node[key]
                if p.ndim == 5:            # scan-stacked (L, nb, bs, H, Dh)
                    out[key] = p.at[:, dst].set(p[:, src])
                else:                      # (nb, bs, H, Dh)
                    out[key] = p.at[dst].set(p[src])
            return out

        def walk(node):
            if _is_clustered_kv(node):
                return leaf(node)
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(cache)

    def _absorb_paged_impl(self, cache, j, lengths, target, bt_row, ccfg):
        """Paged twin of ``_absorb_impl``: gather slot j's tail blocks
        into ring order, fold the aged entries into its centroids, write
        back centroids/counts/cov only (the pool bytes are untouched —
        absorb never moves tail data)."""
        keys = attn.CLUSTERED_SLOT_KEYS

        def leaf(node):
            stacked = node["k_cents"].ndim == 5
            ax = 1 if stacked else 0
            sub = {k: jax.lax.dynamic_slice_in_dim(node[k], j, 1, axis=ax)
                   for k in keys}
            kt = self._gather_tail_rows(node["k_tail"], bt_row)
            vt = self._gather_tail_rows(node["v_tail"], bt_row)
            if stacked:
                lyr = node["k_cents"].shape[0]
                flat = {k: v.reshape((lyr,) + v.shape[2:])
                        for k, v in sub.items()}
                flat["k_tail"], flat["v_tail"] = kt, vt
                got = kv_compress.absorb_chunk(
                    flat, jnp.full((lyr,), lengths, jnp.int32),
                    jnp.full((lyr,), target, jnp.int32), ccfg)
                got = {k: got[k][:, None] for k in keys}
            else:
                sub["k_tail"], sub["v_tail"] = kt[None], vt[None]
                got = kv_compress.absorb_chunk(
                    sub, jnp.full((1,), lengths, jnp.int32),
                    jnp.full((1,), target, jnp.int32), ccfg)
            return dict(node, **{
                k: jax.lax.dynamic_update_slice_in_dim(
                    node[k], got[k].astype(node[k].dtype), j, axis=ax)
                for k in keys})

        def walk(node):
            if _is_clustered_kv(node):
                return leaf(node)
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(cache)

    def _compact_paged_impl(self, cache, lengths, bt, ccfg):
        """Paged twin of ``compact_kv``'s recompaction: gather every
        slot's tail blocks into the dense ring layout through the block
        table (B, T), re-compact incrementally, keep the pool bytes and
        write back centroids/counts/cov.  The engine then returns blocks
        whose positions the new frontier covers to the free list (host
        side — the give-back is bookkeeping, not data movement)."""
        keys = attn.CLUSTERED_SLOT_KEYS

        def leaf(node):
            stacked = node["k_cents"].ndim == 5
            kt = self._gather_tail_rows(node["k_tail"], bt)
            vt = self._gather_tail_rows(node["v_tail"], bt)
            if stacked:
                lyr, b = node["k_cents"].shape[:2]
                flat = {k: node[k].reshape((lyr * b,) + node[k].shape[2:])
                        for k in keys}
                flat["k_tail"] = kt.reshape((lyr * b,) + kt.shape[2:])
                flat["v_tail"] = vt.reshape((lyr * b,) + vt.shape[2:])
                ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32),
                                      (lyr, b)).reshape(-1)
                got = kv_compress.recompact_clustered(flat, ln, ccfg)
                got = {k: got[k].reshape((lyr, b) + got[k].shape[1:])
                       for k in keys}
            else:
                b = node["k_cents"].shape[0]
                dense = {k: node[k] for k in keys}
                dense["k_tail"], dense["v_tail"] = kt, vt
                ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
                got = kv_compress.recompact_clustered(dense, ln, ccfg)
            return dict(node,
                        **{k: got[k].astype(node[k].dtype) for k in keys})

        def walk(node):
            if _is_clustered_kv(node):
                return leaf(node)
            if isinstance(node, dict):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(cache)

    # admission-time conversion of a fresh (B=1) exact prefill cache into
    # the engine's clustered layout; ``template`` marks which leaves are
    # clustered (G layers) vs exact (sliding-window rings, SSM state, ...)
    def _clusterize(self, c1, template, plen: int, ccfg):
        C, R = ccfg.n_clusters, ccfg.keep_recent

        def leaf(src, tpl):
            if not (_is_clustered_kv(tpl) and _is_exact_kv(src)):
                return src
            k, v = src["k"], src["v"]
            stacked = k.ndim == 5            # (L, 1, S, H, Dh) scan region
            if stacked:
                l = k.shape[0]
                k = k.reshape((l,) + k.shape[2:])
                v = v.reshape((l,) + v.shape[2:])
            b = k.shape[0]
            # the tail-only (cov=0) form is loss-free only while every
            # prompt position survives in the ring until the first global
            # compaction, which may be up to ``refresh`` steps away —
            # longer prompts must build centroids at admission
            if plen <= R - ccfg.refresh:
                if k.shape[1] < R:
                    # quota layouts size the ring at max_seq; a prefill
                    # cache shorter than that (bucketed prompt) zero-pads
                    # up — the extra offsets sit outside [0, plen) and
                    # stay masked until decode writes them
                    pad = [(0, 0)] * k.ndim
                    pad[1] = (0, R - k.shape[1])
                    k = jnp.pad(k, pad)
                    v = jnp.pad(v, pad)
                dt = k.dtype
                h, dh = k.shape[2], k.shape[3]
                out = {
                    "k_cents": jnp.zeros((b, C, h, dh), dt),
                    "v_cents": jnp.zeros((b, C, h, dh), dt),
                    "counts": jnp.zeros((b, C, h), jnp.float32),
                    # positions 0..plen-1 sit at ring slots 0..plen-1
                    "k_tail": k[:, :R],
                    "v_tail": v[:, :R],
                    "cov": jnp.zeros((b,), jnp.int32),
                }
            else:
                lengths = jnp.full((b,), plen, jnp.int32)
                out = kv_compress.compress_cache_batched(k, v, lengths, ccfg)
            if stacked:
                out = {kk: vv[:, None] for kk, vv in out.items()}
            return out

        def walk(src, tpl):
            if _is_clustered_kv(tpl):
                return leaf(src, tpl)
            if isinstance(src, dict):
                return {kk: walk(vv, tpl[kk]) for kk, vv in src.items()}
            if isinstance(src, list):
                return [walk(vv, tt) for vv, tt in zip(src, tpl)]
            return src

        return walk(c1, template)

    # scatter one (B=1) request cache into engine slot j.  prefix/tail
    # leaves carry batch on axis 0, scan-stacked leaves on axis 1.
    def _write_slot_impl(self, dst, src, j):
        def upd(axis):
            def f(d, s):
                idx = (0,) * axis + (j,) + (0,) * (d.ndim - axis - 1)
                return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), idx)
            return f

        out = dict(dst)
        for key in ("prefix", "tail"):
            out[key] = [jax.tree.map(upd(0), dc, sc)
                        for dc, sc in zip(dst[key], src[key])]
        if "scan" in dst:
            out["scan"] = jax.tree.map(upd(1), dst["scan"], src["scan"])
        return out

    # ------------------------------------------------------------------
    # memory management: batched clustered-KV compaction
    # ------------------------------------------------------------------

    def compact_kv(self, cache, t, ccfg: "kv_compress.KVCompressConfig"):
        """Compress every global-attention layer's KV into clustered form
        (median centroids + counts + exact tail ring) in single jitted
        vmap-over-(batch ⊕ head) calls — no Python loop over batch, head,
        or stacked layer.  Exact leaves are compressed from scratch;
        already-clustered leaves are incrementally re-compacted with
        warm-started centroids (streaming update between decode bursts).
        ``t`` is a scalar length or a per-slot (B,) vector.

        Only leaves that a clustered-mode cache would hold in clustered
        form (global-attention layers) are touched — sliding-window ring
        buffers, SSM/RG-LRU state, and int8 caches pass through, guided
        by a structural template (shapes only, nothing allocated)."""
        tkey = (ccfg.n_clusters, ccfg.keep_recent)
        template = self._compact_templates.get(tkey)
        if template is None:
            template = jax.eval_shape(
                lambda: tfm.init_cache(
                    self.cfg, 1, self.scfg.max_seq, kv_mode="clustered",
                    kv_clusters=ccfg.n_clusters, kv_tail=ccfg.keep_recent))
            self._compact_templates[tkey] = template

        def lengths_for(b):
            return jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))

        def compress_exact(node):
            k, v = node["k"], node["v"]
            if k.shape[-3] <= ccfg.n_clusters + ccfg.keep_recent:
                return node  # not worth compressing
            stacked = k.ndim == 5            # (L, B, S, H, Dh) scan region
            if stacked:
                l, b = k.shape[:2]
                lengths = jnp.broadcast_to(lengths_for(b), (l, b)).reshape(-1)
                out = kv_compress.compress_cache_batched(
                    k.reshape((l * b,) + k.shape[2:]),
                    v.reshape((l * b,) + v.shape[2:]), lengths, ccfg)
                return {kk: vv.reshape((l, b) + vv.shape[1:])
                        for kk, vv in out.items()}
            return kv_compress.compress_cache_batched(
                k, v, lengths_for(k.shape[0]), ccfg)

        def recompact(node):
            stacked = node["k_cents"].ndim == 5
            if stacked:
                l, b = node["k_cents"].shape[:2]
                flat = {kk: vv.reshape((l * b,) + vv.shape[2:])
                        for kk, vv in node.items()}
                lengths = jnp.broadcast_to(lengths_for(b), (l, b)).reshape(-1)
                out = kv_compress.recompact_clustered(flat, lengths, ccfg)
                return {kk: vv.reshape((l, b) + vv.shape[1:])
                        for kk, vv in out.items()}
            return kv_compress.recompact_clustered(
                node, lengths_for(node["k_cents"].shape[0]), ccfg)

        def walk(node, tpl):
            if _is_clustered_kv(tpl):
                if _is_clustered_kv(node):
                    return recompact(node)
                if _is_exact_kv(node) and node["k"].ndim in (4, 5):
                    return compress_exact(node)
                return node
            if isinstance(node, dict) and isinstance(tpl, dict):
                return {kk: walk(vv, tpl.get(kk)) for kk, vv in node.items()}
            if isinstance(node, list) and isinstance(tpl, list):
                return [walk(vv, tt) for vv, tt in zip(node, tpl)]
            return node

        return walk(cache, template)

    # ------------------------------------------------------------------
    # static batch-at-a-time path (baseline for the serve benchmark)
    # ------------------------------------------------------------------

    def _serve_static(self, requests, prompts) -> List[Completion]:
        plan = self._plan(requests)
        by_uid = {r.uid: r for r in requests}
        out: List[Completion] = []
        for batch_uids in plan.batches:
            out.extend(self._serve_batch(batch_uids, by_uid, prompts))
        self.metrics.begin_serve()
        self.metrics.gauge(
            "plan_waste", "padding waste of the static batch plan"
        ).set(plan.waste)
        self.last_stats = self.metrics.flat_view()
        return out

    def _serve_batch(self, uids, by_uid, prompts) -> List[Completion]:
        cfg, scfg = self.cfg, self.scfg
        reqs = [by_uid[u] for u in uids]
        plen = max(r.prompt_len for r in reqs)
        gen = max(r.max_new_tokens for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            p = prompts[r.uid][-plen:]
            toks[i, plen - len(p):] = p  # left-pad

        t0 = time.perf_counter()
        logits, cache = self._prefill(jnp.asarray(toks), jnp.int32(plen - 1))
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        gen_toks = [new]
        for i in range(gen - 1):
            logits, cache = self._decode(cache, new, jnp.int32(plen + i))
            new = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            gen_toks.append(new)
        jax.block_until_ready(new)
        t2 = time.perf_counter()

        gen_arr = np.concatenate([np.asarray(g) for g in gen_toks], axis=1)
        outs = []
        for i, r in enumerate(reqs):
            outs.append(Completion(
                uid=r.uid,
                tokens=gen_arr[i, :r.max_new_tokens].tolist(),
                prefill_ms=(t1 - t0) * 1e3 / b,
                decode_ms=(t2 - t1) * 1e3 / b))
        return outs
