"""Serving telemetry: typed metrics registry, request-lifecycle tracer, exporters.

Three layers, all host-side (nothing here runs inside jit):

* :class:`MetricsRegistry` — named counters / gauges / histograms that the
  engine, scheduler, pool, and template store register into instead of poking
  string keys.  ``Server.last_stats`` is regenerated from the registry as a
  backward-compatible flat view, so every historical key keeps working.
  ``begin_serve()`` drops per-serve metrics so dynamic keys (per-cluster,
  per-shard, per-scheduler) from a previous serve or mesh shape can never leak
  into the next serve's stats; lifetime ``*_total`` metrics opt out with
  ``persist=True``.

* :class:`Tracer` — per-request lifecycle spans (queued → admit → prefill
  chunks → first token → decode → compact/absorb → preempt/swap → resume →
  finish/shed) and per-engine-step events, stamped with wall-clock, token
  position, and pool-block deltas.  Disabled by default; when off the engine
  never constructs event dicts.

* Exporters — JSONL event log and Chrome trace-event JSON loadable in
  Perfetto (one process per data shard, one thread per slot), plus
  :func:`validate_trace` / :func:`validate_chrome_file` schema checks used by
  tests and CI.

Event schema (internal form)::

    {"name": str, "ph": "i" | "X", "ts": float_us, "dur": float_us (X only),
     "pid": int_data_shard, "tid": "engine" | "queue" | "slot<K>",
     "uid": int | None, "args": {...}}

``ts`` is microseconds relative to the serve's ``t0``.
"""

from __future__ import annotations

import contextlib
import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

TRACE_SCHEMA = "repro-serve-trace-v1"


@dataclass(frozen=True)
class TelemetryConfig:
    """Per-server telemetry switches.

    trace:        record lifecycle + engine-step events (host-side only).
    jax_profiler: wrap jitted launches in ``jax.profiler`` annotations so
                  device profiles line up with the host timeline.
    max_events:   tracer ring cap; events past it are counted as dropped.
    """

    trace: bool = False
    jax_profiler: bool = False
    max_events: int = 1_000_000


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class Counter:
    """Monotone per-serve (or lifetime, with persist=True) counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", persist: bool = False):
        self.name = name
        self.help = help
        self.persist = persist
        self.value = 0.0

    def add(self, v: float = 1.0) -> None:
        self.value += float(v)

    def set_to(self, v: float) -> None:
        """Republish a lifetime total (monotone: never moves backwards)."""
        self.value = max(self.value, float(v))

    def view(self) -> Dict[str, float]:
        return {self.name: self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", persist: bool = False):
        self.name = name
        self.help = help
        self.persist = persist
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def view(self) -> Dict[str, float]:
        return {self.name: self.value}


#: Default histogram bucket upper bounds, in *output* units (after ``scale``).
#: Powers of two from 2^-6 to 2^15 — spans sub-ms to ~half a minute when the
#: output unit is milliseconds.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(2.0 ** e for e in range(-6, 16))


class Histogram:
    """Fixed-bucket histogram with exact quantiles while samples are retained.

    ``observe()`` takes values in the *input* unit (e.g. seconds); ``scale``
    converts to the output unit for the exported ``<name>_p<q><suffix>`` keys
    (e.g. ``scale=1e3, suffix="_ms"``).  While fewer than ``max_samples``
    observations have been made, quantiles are exact ``np.percentile`` over
    the raw samples — bit-identical to the historical ad-hoc percentile
    helpers.  Past the cap, quantiles interpolate within the fixed buckets.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        persist: bool = False,
        quantiles: Sequence[float] = (50, 95, 99),
        scale: float = 1.0,
        suffix: str = "",
        buckets: Optional[Sequence[float]] = None,
        max_samples: int = 65536,
    ):
        self.name = name
        self.help = help
        self.persist = persist
        self.quantiles = tuple(quantiles)
        self.scale = float(scale)
        self.suffix = suffix
        self.buckets = tuple(buckets) if buckets is not None else DEFAULT_BUCKETS
        self.max_samples = int(max_samples)
        self.bucket_counts = np.zeros(len(self.buckets) + 1, dtype=np.int64)
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0  # in output units

    def observe(self, v: float) -> None:
        out = float(v) * self.scale
        self.bucket_counts[int(np.searchsorted(self.buckets, out))] += 1
        self.count += 1
        self.total += out
        if len(self.samples) < self.max_samples:
            self.samples.append(float(v))

    @property
    def exact(self) -> bool:
        return self.count == len(self.samples)

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if self.exact:
            return float(np.percentile(np.asarray(self.samples), q) * self.scale)
        return self._bucket_quantile(q)

    def _bucket_quantile(self, q: float) -> float:
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.bucket_counts):
            nxt = cum + int(c)
            if nxt >= target and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.buckets[-1] * 2.0
                frac = (target - cum) / max(int(c), 1)
                return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))
            cum = nxt
        return float(self.buckets[-1])

    def key(self, q: float) -> str:
        return f"{self.name}_p{int(q)}{self.suffix}"

    def view(self) -> Dict[str, float]:
        return {self.key(q): self.quantile(q) for q in self.quantiles}


class MetricsRegistry:
    """Ordered get-or-create registry of typed metrics.

    Per-serve metrics (``persist=False``, the default) are dropped at
    ``begin_serve()``; lifetime metrics survive.  ``flat_view()`` renders the
    backward-compatible ``last_stats`` dict in registration order.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind: str, factory) -> Any:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, not {kind}"
                )
            return m
        m = factory()
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", persist: bool = False) -> Counter:
        return self._get(name, "counter", lambda: Counter(name, help, persist))

    def gauge(self, name: str, help: str = "", persist: bool = False) -> Gauge:
        return self._get(name, "gauge", lambda: Gauge(name, help, persist))

    def histogram(self, name: str, help: str = "", persist: bool = False, **kw) -> Histogram:
        return self._get(name, "histogram", lambda: Histogram(name, help, persist, **kw))

    def begin_serve(self) -> None:
        """Drop every per-serve metric so stale dynamic keys cannot leak."""
        self._metrics = {
            k: m for k, m in self._metrics.items() if m.persist
        }

    def flat_view(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for m in self._metrics.values():
            out.update(m.view())
        return out

    def reference_table(self) -> str:
        """Markdown reference of every registered metric (for docs)."""
        lines = ["| metric | type | description |", "|---|---|---|"]
        for m in self._metrics.values():
            tag = " (lifetime)" if m.persist else ""
            if m.kind == "histogram":
                keys = ", ".join(f"`{m.key(q)}`" for q in m.quantiles)
                lines.append(f"| {keys} | histogram{tag} | {m.help} |")
            else:
                lines.append(f"| `{m.name}` | {m.kind}{tag} | {m.help} |")
        return "\n".join(lines)


def reference_registry() -> "MetricsRegistry":
    """A registry holding every metric the serving stack can publish.

    Built by running a canonical battery of tiny in-memory serves — the
    real registration calls in server/scheduler/pool/template-store with
    their real help strings, so the generated reference can never drift
    from the code.  Battery legs (each adds the families the previous
    legs can't reach):

    1. mixed 'GM' clustered + paged + chunked + SLO scheduler — base
       engine metrics, frontier/recurrent retirement, both layer-state
       byte gauges, pool accounting, sched_* ladder
    2. windowed 'GL' clustered + paged + chunked — window retirement
    3. exact-KV paged — quota retirement
    4. clustered + paged + template store — template_* / prefix_*
    5. clustered dense — the non-paged KV-footprint gauges
    6. static batch engine — plan_waste

    Mesh-only metrics (per-data-shard waste) are registered directly:
    the battery must run on one device.
    """
    import jax
    import numpy as np
    from dataclasses import replace as dataclasses_replace

    from repro.core import kv_compress
    from repro.core.request_cluster import Request
    from repro.models import transformer as tfm
    from repro.models.config import ModelConfig, SSMConfig
    from repro.runtime.kv_pool import PagedKVConfig
    from repro.runtime.scheduler import SLOConfig
    from repro.runtime.server import Server, ServerConfig
    from repro.runtime.template_store import TemplateStoreConfig

    gm = ModelConfig(name="ref-gm", family="hybrid", n_layers=2,
                     d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                     d_ff=64, vocab=64, pad_vocab_multiple=16,
                     dtype="float32", layer_pattern="GM",
                     ssm=SSMConfig(d_state=8, d_conv=4, expand=2,
                                   head_dim=16, n_groups=1, chunk=16))
    g = ModelConfig(name="ref-g", family="dense", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab=64, pad_vocab_multiple=16, dtype="float32")
    gl = dataclasses_replace(g, name="ref-gl", layer_pattern="GL",
                             sliding_window=8)
    rng = np.random.default_rng(0)
    reqs = [Request(i, int(l), n) for i, (l, n) in
            enumerate([(20, 6), (7, 5), (14, 4)])]
    prompts = {r.uid: rng.integers(0, 64, size=(r.prompt_len,)).astype(
        np.int32) for r in reqs}
    ccfg = kv_compress.KVCompressConfig(n_clusters=4, iters=2,
                                        keep_recent=8, refresh_every=4)
    merged = MetricsRegistry()

    import re as _re
    instanced = _re.compile(r"template_cluster\d+_")

    def run(cfg, scfg):
        srv = Server(cfg, scfg,
                     tfm.init_params(jax.random.PRNGKey(0), cfg))
        srv.serve(reqs, prompts)
        for name, m in srv.metrics._metrics.items():
            # collapse per-instance dynamic gauges to one <C> placeholder
            # row each (registered below) — which cluster ids exist is a
            # traffic artifact, not part of the metrics surface
            if not instanced.match(name):
                merged._metrics.setdefault(name, m)

    run(gm, ServerConfig(batch_size=2, max_seq=48, kv_compress=ccfg,
                         prefill_chunk=8,
                         paged=PagedKVConfig(block_size=4),
                         scheduler=SLOConfig()))
    run(gl, ServerConfig(batch_size=2, max_seq=48, kv_compress=ccfg,
                         prefill_chunk=8,
                         paged=PagedKVConfig(block_size=4)))
    run(g, ServerConfig(batch_size=2, max_seq=48,
                        paged=PagedKVConfig(block_size=4)))
    run(g, ServerConfig(batch_size=2, max_seq=48, kv_compress=ccfg,
                        prefill_chunk=8, paged=PagedKVConfig(block_size=4),
                        template_store=TemplateStoreConfig()))
    run(g, ServerConfig(batch_size=2, max_seq=48, kv_compress=ccfg))
    run(g, ServerConfig(batch_size=2, max_seq=48, engine="static",
                        use_clustered_batching=False))
    # per-cluster placeholders (help strings mirror template_store.py)
    merged.gauge("template_cluster<C>_cohesion",
                 "cluster <C>: matched/prompt cohesion")
    merged.gauge("template_cluster<C>_hit_rate",
                 "cluster <C>: hits per member admission")
    merged.gauge("template_cluster<C>_bytes_pinned",
                 "cluster <C>: bytes pinned by its entries")
    # mesh-only (engine registers these when n_data_shards > 1; help
    # strings mirror runtime/server.py)
    merged.gauge("n_data_shards", "data shards this serve")
    merged.gauge("slot_waste_shard<S>",
                 "idle slot-step fraction on data shard <S>")
    return merged


def reference_doc() -> str:
    """The committed ``docs/metrics.md`` content."""
    return (
        "# Serving metrics reference\n\n"
        "Every metric the serving engine can publish into "
        "`Server.last_stats`, in registration order.  Generated by "
        "`python -m repro.runtime.telemetry reference` from the live "
        "registrations (a battery of tiny in-memory serves) — do not "
        "edit by hand; CI regenerates it and fails on drift.\n\n"
        "Per-serve metrics reset at each `serve()`; metrics tagged "
        "*(lifetime)* persist across serves on the same `Server`.  "
        "`<S>` ranges over data shards on a mesh; "
        "`template_cluster<C>_*` gauges appear per online traffic "
        "cluster when a template store is configured.\n\n"
        + reference_registry().reference_table() + "\n")


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class Tracer:
    """Host-side event recorder for one serve at a time."""

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self.t0 = 0.0
        self.n_shards = 1

    def begin_serve(self, t0: float, n_shards: int = 1) -> None:
        self.events = []
        self.dropped = 0
        self.t0 = float(t0)
        self.n_shards = max(int(n_shards), 1)

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def event(
        self,
        name: str,
        pid: int = 0,
        tid: str = "engine",
        uid: Optional[int] = None,
        t: Optional[float] = None,
        **args: Any,
    ) -> None:
        """Record an instant event at wall-clock ``t`` (defaults to now)."""
        if t is None:
            import time

            t = time.perf_counter()
        self._push(
            {
                "name": name,
                "ph": "i",
                "ts": (t - self.t0) * 1e6,
                "pid": int(pid),
                "tid": tid,
                "uid": uid,
                "args": args,
            }
        )

    def span(
        self,
        name: str,
        t_start: float,
        t_end: float,
        pid: int = 0,
        tid: str = "engine",
        uid: Optional[int] = None,
        **args: Any,
    ) -> None:
        self._push(
            {
                "name": name,
                "ph": "X",
                "ts": (t_start - self.t0) * 1e6,
                "dur": max((t_end - t_start) * 1e6, 0.0),
                "pid": int(pid),
                "tid": tid,
                "uid": uid,
                "args": args,
            }
        )

    def finish(self) -> List[Dict[str, Any]]:
        evs = self.events
        self.events = []
        return evs


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

#: (span/instant name, registry total key) pairs reconciled by validate_trace.
_TOTALS: Tuple[Tuple[str, str], ...] = (
    ("swap_out", "sched_swaps_out"),
    ("resume", "sched_swaps_in"),
    ("shed", "sched_sheds"),
    ("prefill_chunk", "prefill_chunks"),
    ("absorb", "kv_absorbs"),
    ("compact", "kv_compactions"),
    ("engine_step", "decode_steps"),
)


def validate_trace(
    events: Sequence[Dict[str, Any]],
    totals: Optional[Dict[str, float]] = None,
) -> List[str]:
    """Check trace-schema invariants; return a list of problem strings.

    1. every uid that ever ran (has a ``run`` span) emits exactly one terminal
       event (``finish`` or ``shed``); no uid emits more than one terminal;
    2. X-spans nest well-formed per (pid, tid) track;
    3. swap_out / resume events pair up per uid (no double-park, no resume of
       a non-parked uid; a still-parked uid must have a ``shed`` terminal);
    4. when ``totals`` is given, event counts reconcile with registry totals
       and run-span token deltas sum to ``gen_tokens``.
    """
    problems: List[str] = []

    ran = {e["uid"] for e in events if e["name"] == "run" and e["uid"] is not None}
    terminals: Dict[int, int] = {}
    for e in events:
        if e["name"] in ("finish", "shed") and e["uid"] is not None:
            terminals[e["uid"]] = terminals.get(e["uid"], 0) + 1
    for uid in sorted(ran):
        c = terminals.get(uid, 0)
        if c != 1:
            problems.append(f"uid {uid}: {c} terminal events (expected exactly 1)")
    for uid, c in sorted(terminals.items()):
        if uid not in ran and c > 1:
            problems.append(f"uid {uid}: {c} terminal events without a run span")

    # span nesting per track
    by_track: Dict[Tuple[int, str], List[Dict[str, Any]]] = {}
    for e in events:
        if e["ph"] == "X":
            by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    for (pid, tid), evs in sorted(by_track.items()):
        evs = sorted(evs, key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: List[float] = []
        for e in evs:
            end = e["ts"] + e.get("dur", 0.0)
            while stack and e["ts"] >= stack[-1]:
                stack.pop()
            if stack and end > stack[-1] + 1e-6:
                problems.append(
                    f"track ({pid},{tid}): span {e['name']!r} at ts={e['ts']:.1f} "
                    f"partially overlaps enclosing span"
                )
                continue
            stack.append(end)

    # swap pairing per uid
    parked: Dict[int, bool] = {}
    for e in sorted(events, key=lambda e: e["ts"]):
        uid = e.get("uid")
        if uid is None:
            continue
        if e["name"] == "swap_out":
            if parked.get(uid):
                problems.append(f"uid {uid}: swap_out while already parked")
            parked[uid] = True
        elif e["name"] == "resume":
            if not parked.get(uid):
                problems.append(f"uid {uid}: resume without matching swap_out")
            parked[uid] = False
    shed_uids = {e["uid"] for e in events if e["name"] == "shed" and e["uid"] is not None}
    for uid, p in sorted(parked.items()):
        if p and uid not in shed_uids:
            problems.append(f"uid {uid}: still parked at end of trace without shed")

    if totals is not None:
        counts: Dict[str, int] = {}
        for e in events:
            counts[e["name"]] = counts.get(e["name"], 0) + 1
        for ev_name, key in _TOTALS:
            if key in totals:
                got, want = counts.get(ev_name, 0), int(totals[key])
                if got != want:
                    problems.append(
                        f"count({ev_name})={got} != {key}={want}"
                    )
        if "gen_tokens" in totals:
            toks = sum(
                int(e["args"].get("tokens", 0))
                for e in events
                if e["name"] == "run"
            )
            if toks != int(totals["gen_tokens"]):
                problems.append(
                    f"run-span token sum {toks} != gen_tokens {int(totals['gen_tokens'])}"
                )

    return problems


def phase_breakdown(events: Sequence[Dict[str, Any]]) -> Dict[str, float]:
    """Per-phase wall-time breakdown (milliseconds) from a trace."""
    out: Dict[str, float] = {}
    for e in events:
        if e["ph"] != "X":
            continue
        ms = e.get("dur", 0.0) / 1e3
        if e["name"] == "engine_step":
            kind = e["args"].get("kind", "decode")
            key = f"phase_{kind}_ms"
        elif e["name"] in ("compact", "absorb", "swap_out", "resume", "prefill"):
            key = f"phase_{e['name']}_ms"
        else:
            continue
        out[key] = out.get(key, 0.0) + ms
    return {k: float(v) for k, v in sorted(out.items())}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def write_jsonl(
    events: Sequence[Dict[str, Any]],
    path: str,
    meta: Optional[Dict[str, Any]] = None,
) -> None:
    with open(path, "w") as f:
        if meta is not None:
            f.write(json.dumps({"schema": TRACE_SCHEMA, **meta}) + "\n")
        for e in events:
            f.write(json.dumps(e) + "\n")


def _tid_num(tid: str) -> int:
    if tid == "engine":
        return 0
    if tid == "queue":
        return 1
    if tid.startswith("slot"):
        return int(tid[4:]) + 2
    return 999


def write_chrome_trace(
    events: Sequence[Dict[str, Any]],
    path: str,
    n_shards: int = 1,
    stats: Optional[Dict[str, float]] = None,
) -> None:
    """Export a Chrome trace-event JSON file loadable in Perfetto.

    One process per data shard, threads ``engine`` / ``queue`` / ``slot<K>``.
    ``stats`` (typically ``server.last_stats``) is embedded in ``otherData``
    so :func:`validate_chrome_file` can reconcile counts offline.
    """
    traceEvents: List[Dict[str, Any]] = []
    tids_seen: Dict[int, Dict[str, int]] = {}
    for e in events:
        pid = int(e["pid"])
        tid = _tid_num(e["tid"])
        tids_seen.setdefault(pid, {})[e["tid"]] = tid
        args = dict(e.get("args") or {})
        if e.get("uid") is not None:
            args["uid"] = e["uid"]
        out = {
            "name": e["name"],
            "cat": "serve",
            "ph": e["ph"],
            "ts": e["ts"],
            "pid": pid,
            "tid": tid,
            "args": args,
        }
        if e["ph"] == "X":
            out["dur"] = e.get("dur", 0.0)
        else:
            out["s"] = "t"
        traceEvents.append(out)
    for pid, tids in sorted(tids_seen.items()):
        traceEvents.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": f"data shard {pid}"},
            }
        )
        for tname, tnum in sorted(tids.items(), key=lambda kv: kv[1]):
            traceEvents.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": pid,
                    "tid": tnum,
                    "args": {"name": tname},
                }
            )
            traceEvents.append(
                {
                    "name": "thread_sort_index",
                    "ph": "M",
                    "pid": pid,
                    "tid": tnum,
                    "args": {"sort_index": tnum},
                }
            )
    other: Dict[str, Any] = {"schema": TRACE_SCHEMA, "n_shards": int(n_shards)}
    if stats is not None:
        other["last_stats"] = {k: float(v) for k, v in stats.items()}
    with open(path, "w") as f:
        json.dump(
            {
                "traceEvents": traceEvents,
                "displayTimeUnit": "ms",
                "otherData": other,
            },
            f,
        )


def events_from_chrome(obj: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Reconstruct internal events from a Chrome trace-event JSON object."""
    names: Dict[Tuple[int, int], str] = {}
    for e in obj.get("traceEvents", []):
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[(int(e["pid"]), int(e["tid"]))] = e["args"]["name"]
    out: List[Dict[str, Any]] = []
    for e in obj.get("traceEvents", []):
        if e.get("ph") not in ("i", "X"):
            continue
        args = dict(e.get("args") or {})
        uid = args.pop("uid", None)
        ev = {
            "name": e["name"],
            "ph": e["ph"],
            "ts": float(e["ts"]),
            "pid": int(e["pid"]),
            "tid": names.get((int(e["pid"]), int(e["tid"])), "engine"),
            "uid": uid,
            "args": args,
        }
        if e["ph"] == "X":
            ev["dur"] = float(e.get("dur", 0.0))
        out.append(ev)
    return out


def validate_chrome_file(path: str, reconcile: bool = True) -> List[str]:
    """Parse + validate an exported Chrome trace file; return problems."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable chrome trace {path}: {e}"]
    problems: List[str] = []
    other = obj.get("otherData") or {}
    if other.get("schema") != TRACE_SCHEMA:
        problems.append(
            f"schema mismatch: {other.get('schema')!r} != {TRACE_SCHEMA!r}"
        )
    events = events_from_chrome(obj)
    totals = other.get("last_stats") if reconcile else None
    problems.extend(validate_trace(events, totals=totals))
    return problems


def validate_jsonl_file(path: str, reconcile: bool = True) -> List[str]:
    try:
        meta: Optional[Dict[str, Any]] = None
        events: List[Dict[str, Any]] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if "schema" in obj and "ph" not in obj:
                    meta = obj
                    continue
                events.append(obj)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable jsonl trace {path}: {e}"]
    totals = (meta or {}).get("last_stats") if reconcile else None
    return validate_trace(events, totals=totals)


# ---------------------------------------------------------------------------
# jax.profiler integration
# ---------------------------------------------------------------------------


def annotation(name: str):
    """A ``jax.profiler`` trace annotation, or a no-op if unavailable."""
    try:
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# CLI: python -m repro.runtime.telemetry validate <trace.json> ...
# ---------------------------------------------------------------------------


def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.runtime.telemetry")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="validate exported trace files")
    v.add_argument("paths", nargs="+")
    v.add_argument(
        "--no-reconcile",
        action="store_true",
        help="skip reconciling event counts against embedded last_stats",
    )
    r = sub.add_parser(
        "reference",
        help="emit the metrics reference doc (docs/metrics.md)",
    )
    r.add_argument(
        "--check",
        metavar="PATH",
        default=None,
        help="compare against an existing file instead of printing; "
        "exit 1 if it is out of date",
    )
    args = ap.parse_args(argv)

    if args.cmd == "reference":
        doc = reference_doc()
        if args.check is None:
            print(doc, end="")
            return 0
        try:
            with open(args.check, "r", encoding="utf-8") as f:
                on_disk = f.read()
        except OSError as e:
            print(f"{args.check}: {e}")
            return 1
        if on_disk != doc:
            print(f"{args.check}: out of date — regenerate with "
                  "`python -m repro.runtime.telemetry reference > "
                  f"{args.check}`")
            return 1
        print(f"{args.check}: up to date")
        return 0

    rc = 0
    for path in args.paths:
        if path.endswith(".jsonl"):
            problems = validate_jsonl_file(path, reconcile=not args.no_reconcile)
        else:
            problems = validate_chrome_file(path, reconcile=not args.no_reconcile)
        if problems:
            rc = 1
            print(f"{path}: {len(problems)} problem(s)")
            for p in problems:
                print(f"  - {p}")
        else:
            print(f"{path}: ok")
    return rc


if __name__ == "__main__":
    raise SystemExit(_main())
