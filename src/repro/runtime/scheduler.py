"""SLO-aware scheduling: priority preemption, host-memory swap, and
graceful brownout under overload.

The paged clustered-KV engine (runtime/server.py) already survives pool
pressure by deferring admissions, sweeping covered blocks, and evicting
unpinned prefix entries — but those rungs are priority-blind: a burst of
best-effort batch traffic can hold every slot and block while an
interactive request queues behind it, and sustained overload still ends
in ``PoolExhausted``.  This module adds the QoS layer on top:

  * requests carry an SLO class (``core.request_cluster.Request.priority``,
    larger = more important) and an optional soft TTFT deadline;
  * under slot or pool pressure the engine **preempts** the cheapest
    lower-priority in-flight slot: its slot snapshot
    (``clustered_slot_state`` — the PR 5 prefix-snapshot format, which
    also carries any recurrent-family (conv, ssm)/(conv, h) state whole)
    plus its mapped tail-ring block payloads are gathered to **host
    memory**, the blocks go back to the pool
    (``BlockPool.release_slot``), and the request parks on a swap
    backlog;
  * a parked request **resumes mid-stream** when capacity returns:
    blocks whose ``(gid, generation)`` survived untouched are re-adopted
    without a re-upload (the COW rule makes a live block's payload
    immutable, so the device bytes provably still match the host copy),
    the rest are re-allocated and scattered back from the host copy.
    Because every slot's clustered state is a deterministic function of
    its own token stream (per-slot compaction cadence, PR 5), the
    resumed request's greedy tokens are bit-identical to an
    uninterrupted run — preemption is schedule-invisible;
  * when even preemption cannot make progress, best-effort load is
    **shed** (partial tokens returned, blocks freed) before any
    high-class request is failed — ``PoolExhausted`` only fires once all
    remaining work is the protected class.

The brownout ladder, cheapest rung first, each step counted in
``Server.last_stats`` (``sched_*`` keys):

    defer  → retry the admission later (existing machinery, now counted)
    preempt→ swap a lower-priority slot out to host memory
    swap-in→ resume a parked request when capacity returns
    shed   → drop best-effort work that can no longer be served

Victim choice follows the Mettu–Plaxton online-median framing the
ROADMAP points at: among lower-priority active slots, the one mapping
the *fewest* pool blocks is the cheapest eviction — its ring is mostly
covered, i.e. the centroids already summarize it, so swapping it moves
the least exact KV (the swap snapshot is "just another compressed
summary tier" in the stream-clustering view).

This module is host-only and engine-agnostic: it owns the policy
(victim selection, backlog ordering, shed eligibility) and the
accounting; the Server owns the device work (gather/scatter jits,
placement) and calls in at its clean step boundaries.  That split keeps
the policy unit-testable without a model (tests/test_scheduler.py) and
lets the Hypothesis state machine (tests/test_properties.py) drive
scheduler + pool together with no device arrays at all.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["SLOConfig", "SwapRecord", "SLOScheduler"]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Engine-facing SLO knobs (``ServerConfig.scheduler``).

    ``high_class``: requests with ``priority >= high_class`` form the
    protected class — they are never preempted in favor of lower
    classes, never shed, and their TTFT is what the brownout ladder
    defends.  ``shed_on_exhaustion``: when even preemption cannot free a
    block and zero forward progress is possible, drop best-effort work
    (partial tokens returned) instead of raising ``PoolExhausted``; the
    exception still fires if only protected work remains.
    ``max_swapped``: cap on concurrently parked requests (0 = slots
    count, the natural bound — every parked request beyond the slot
    count would have been queue-deferred anyway).
    ``priority_admission``: stable priority-first ordering of the
    pending queue — the admission-control half of the QoS story, and
    what lets a protected request arriving behind a deep best-effort
    backlog see a p95 TTFT bounded by the protected class's own demand
    instead of the whole queue's.  Disable to model strict
    arrival-order admission (an online scheduler that cannot see
    future arrivals), where priority acts only through preemption and
    resume ordering."""
    high_class: int = 1
    shed_on_exhaustion: bool = True
    max_swapped: int = 0
    priority_admission: bool = True


@dataclasses.dataclass
class SwapRecord:
    """Everything needed to resume a preempted request bit-identically,
    host-resident.  ``snap``/``tails`` are host (numpy) pytrees in the
    PR 5 prefix-snapshot format plus the gathered block payloads;
    ``held`` maps ring-block index → (gid, generation-at-release) for
    the re-adoption fast path; ``epoch`` stamps the server config/weights
    the snapshot was taken under (a resume under any other epoch must
    re-prefill rather than restore — same protocol as the template
    store)."""
    uid: int
    priority: int
    pos: int                    # tokens fed (watermark t)
    cur: int                    # last sampled token id (next step input)
    fed: int                    # prompt tokens consumed
    since_tok: int              # per-slot compaction cadence phase
    cov: int                    # coverage frontier at swap-out
    max_new_tokens: int
    deadline_ms: float
    held: Dict[int, Tuple[int, int]]
    snap: Any                   # host clustered_slot_state pytree
    tails: Any                  # host {k_tail, v_tail} payload pytree
    epoch: Any
    seq: int                    # swap-out order (FIFO within a class)
    n_blocks_swapped: int = 0   # mapped blocks at swap-out (accounting)
    state_bytes: int = 0        # recurrent-family state bytes riding the
    #                             snapshot (core/layer_state.py): the
    #                             fixed-size (conv, ssm)/(conv, h) price a
    #                             mixed-family slot pays per swap on top
    #                             of its mapped tail blocks
    hold: bool = False          # parked by a zero-progress (within-class)
    #                             preemption: not resumable until the
    #                             engine decodes real tokens again, or
    #                             the freed blocks would bounce straight
    #                             back and recreate the stall (live-lock)


class SLOScheduler:
    """Host-side policy + accounting for one ``serve()`` call.

    The Server constructs one per serve (the swap backlog never
    outlives the serve — parked requests either resume or shed before
    the serve returns, so cross-serve template-store state is
    untouched).  All methods are O(slots) or O(backlog) host work.
    """

    def __init__(self, cfg: SLOConfig, n_slots: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_swapped = cfg.max_swapped or n_slots
        self._backlog: List[SwapRecord] = []
        self._seq = 0
        self.shed_uids: set = set()
        # brownout counters (surfaced as last_stats["sched_*"])
        self.deferrals = 0
        self.preemptions = 0
        self.swaps_out = 0
        self.swaps_in = 0
        self.sheds = 0
        self.shed_high = 0          # must stay 0: protected class never shed
        self.readopted_blocks = 0
        self.reuploaded_blocks = 0
        self.swapped_blocks = 0     # currently parked blocks-worth of tail
        self.swapped_peak = 0
        self.swap_bytes = 0         # host bytes currently parked
                                    # (tail KV + recurrent state)

    # ------------------------------------------------------------------
    # class predicates
    # ------------------------------------------------------------------

    def is_high(self, priority: int) -> bool:
        return priority >= self.cfg.high_class

    # ------------------------------------------------------------------
    # victim selection
    # ------------------------------------------------------------------

    def pick_victim(self, candidates: List[Tuple[int, int, int]],
                    below_prio: int) -> Optional[int]:
        """Choose the cheapest preemption victim among active slots.

        ``candidates`` is ``[(priority, swap_cost, slot), ...]`` for the
        admissible slots (caller pre-filters by shard when the pressure
        is shard-local — blocks are shard-local, so only a same-shard
        victim frees usable blocks).  Eligible victims have
        ``priority < below_prio`` strictly (preemption never reorders
        within a class — that would trade one request's SLO for an
        equal one's) and are outside the protected class unless the
        preemptor itself outranks them.  Cheapest = lowest priority
        first, then lowest swap cost, then lowest slot for determinism.
        The cost function belongs to the caller: the engine prices
        heterogeneous layer-state families as mapped-tail-block bytes
        plus the recurrent family's fixed per-slot state bytes
        (core/layer_state.py) — the most-covered slot moves the least
        exact KV, the Mettu–Plaxton cheapest-eviction rule, and for
        all-ring patterns the byte cost is a monotone transform of the
        old mapped-block count so victim choices are unchanged."""
        elig = [(p, nb, j) for (p, nb, j) in candidates if p < below_prio]
        if not elig:
            return None
        return min(elig)[2]

    # ------------------------------------------------------------------
    # swap backlog
    # ------------------------------------------------------------------

    def record_swap(self, rec: SwapRecord) -> None:
        rec.seq = self._seq
        self._seq += 1
        self._backlog.append(rec)
        self.preemptions += 1
        self.swaps_out += 1
        self.swapped_blocks += rec.n_blocks_swapped
        self.swapped_peak = max(self.swapped_peak, self.swapped_blocks)

    def can_swap(self) -> bool:
        return len(self._backlog) < self.max_swapped

    def backlog_size(self) -> int:
        return len(self._backlog)

    def peek_resume(self) -> Optional[SwapRecord]:
        """Next record to resume: highest priority, then FIFO by
        swap-out order within a class (a parked request re-enters ahead
        of later-parked equals — it already paid its admission).
        Records parked by a zero-progress preemption stay held until
        ``clear_holds`` (the engine decoded real tokens again)."""
        elig = [r for r in self._backlog if not r.hold]
        if not elig:
            return None
        return min(elig, key=lambda r: (-r.priority, r.seq))

    def clear_holds(self) -> None:
        """Forward progress happened: held records become resumable."""
        for r in self._backlog:
            r.hold = False

    def pop_record(self, rec: SwapRecord) -> None:
        """Remove a record that resumed (caller already restored it)."""
        self._backlog.remove(rec)
        self.swaps_in += 1
        self.swapped_blocks -= rec.n_blocks_swapped

    def shed_record(self, rec: SwapRecord) -> None:
        """Drop a parked best-effort request (its blocks were already
        released at swap-out — nothing to free)."""
        if self.is_high(rec.priority):
            raise RuntimeError(
                f"refusing to shed protected request uid={rec.uid} "
                f"(priority {rec.priority} >= high_class "
                f"{self.cfg.high_class})")
        self._backlog.remove(rec)
        self.swapped_blocks -= rec.n_blocks_swapped
        self.shed_uids.add(rec.uid)
        self.sheds += 1

    def shed_uid(self, uid: int, priority: int) -> None:
        """Shed a queued or active best-effort request (caller frees any
        blocks the slot held)."""
        if self.is_high(priority):
            raise RuntimeError(
                f"refusing to shed protected request uid={uid} "
                f"(priority {priority} >= high_class "
                f"{self.cfg.high_class})")
        self.shed_uids.add(uid)
        self.sheds += 1

    def pick_shed(self) -> Optional[SwapRecord]:
        """Cheapest parked record to shed under exhaustion: lowest
        priority, then most recently parked (LIFO among equals — the
        longest-parked request is closest to its deadline budget and
        has the best claim on eventually resuming)."""
        elig = [r for r in self._backlog if not self.is_high(r.priority)]
        if not elig:
            return None
        return min(elig, key=lambda r: (r.priority, -r.seq))

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def publish(self, reg) -> None:
        """Publish the brownout counters into a telemetry registry
        (duck-typed).  Key names match :meth:`stats` exactly so the
        registry-generated flat view stays backward compatible."""
        reg.counter("sched_deferrals",
                    "admissions/resumes pushed back for later retry"
                    ).add(self.deferrals)
        reg.counter("sched_preemptions",
                    "slots preempted (swap-out rung)").add(self.preemptions)
        reg.counter("sched_swaps_out",
                    "slot states spilled to host memory").add(self.swaps_out)
        reg.counter("sched_swaps_in",
                    "parked requests resumed mid-stream").add(self.swaps_in)
        reg.counter("sched_sheds",
                    "best-effort requests dropped under brownout"
                    ).add(self.sheds)
        reg.counter("sched_shed_high",
                    "protected-class sheds (must stay 0)").add(self.shed_high)
        reg.gauge("sched_swapped_peak_blocks",
                  "peak blocks-worth of tail KV parked on host"
                  ).set(float(self.swapped_peak))
        reg.counter("sched_readopted_blocks",
                    "resume blocks re-adopted without re-upload"
                    ).add(self.readopted_blocks)
        reg.counter("sched_reuploaded_blocks",
                    "resume blocks re-uploaded from the host copy"
                    ).add(self.reuploaded_blocks)
        reg.gauge("sched_swap_bytes",
                  "host bytes parked (tail KV + recurrent state)"
                  ).set(float(self.swap_bytes))
        reg.gauge("sched_backlog_end",
                  "records still parked at end of serve"
                  ).set(float(len(self._backlog)))

    def stats(self) -> Dict[str, float]:
        return {
            "sched_deferrals": float(self.deferrals),
            "sched_preemptions": float(self.preemptions),
            "sched_swaps_out": float(self.swaps_out),
            "sched_swaps_in": float(self.swaps_in),
            "sched_sheds": float(self.sheds),
            "sched_shed_high": float(self.shed_high),
            "sched_swapped_peak_blocks": float(self.swapped_peak),
            "sched_readopted_blocks": float(self.readopted_blocks),
            "sched_reuploaded_blocks": float(self.reuploaded_blocks),
            "sched_swap_bytes": float(self.swap_bytes),
            "sched_backlog_end": float(len(self._backlog)),
        }
