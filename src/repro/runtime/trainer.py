"""Training runtime: checkpoint/restart fault tolerance, straggler stats.

The loop is deliberately boring — production behaviors live around it:
  * resume: on start, restore the latest committed checkpoint and continue
    from its step; the data pipeline is stateless-seekable so batches
    replay identically,
  * periodic + final checkpoints (async save off the critical path),
  * straggler detection: per-step wall time aggregated with the paper's
    bit-serial median + MAD (median absolute deviation) — a step slower
    than median + 6·MAD is flagged (on a real fleet this triggers
    hot-spare swap; here it is logged),
  * preemption simulation hooks for tests (``fail_at_step``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import bitserial
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    async_ckpt: bool = False
    fail_at_step: Optional[int] = None   # fault-injection for tests
    straggler_mad_factor: float = 6.0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 aw: adamw.AdamWConfig, step_fn: Callable, data,
                 init_params_fn: Optional[Callable] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.aw = aw
        self.step_fn = step_fn
        self.data = data
        self.init_params_fn = init_params_fn or (
            lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.losses: list[float] = []

    def _init_state(self):
        params = self.init_params_fn()
        opt_state = adamw.init(params)
        return params, opt_state, 0

    def restore_or_init(self):
        latest = ckpt.latest_step(self.tcfg.ckpt_dir)
        params, opt_state, start = self._init_state()
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            tree, step = ckpt.restore(self.tcfg.ckpt_dir, tree)
            params, opt_state = tree["params"], tree["opt"]
            start = step
            print(f"[trainer] resumed from step {step}")
        return params, opt_state, start

    def run(self):
        params, opt_state, start = self.restore_or_init()
        pending = None
        for step in range(start, self.tcfg.n_steps):
            if self.tcfg.fail_at_step is not None \
                    and step == self.tcfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            self.losses.append(float(metrics["loss"]))
            self._check_straggler(step, dt)
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"[trainer] step {step + 1}: "
                      f"loss {float(metrics['loss']):.4f} "
                      f"({dt * 1e3:.0f} ms)")
            if (step + 1) % self.tcfg.ckpt_every == 0:
                pending = self._save(params, opt_state, step + 1)
        if pending is not None:
            pending.join()
        self._save(params, opt_state, self.tcfg.n_steps, blocking=True)
        ckpt.prune(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)
        return params, opt_state

    def _save(self, params, opt_state, step, blocking=None):
        tree = {"params": params, "opt": opt_state}
        return ckpt.save(self.tcfg.ckpt_dir, step, tree,
                         blocking=(not self.tcfg.async_ckpt
                                   if blocking is None else blocking))

    def _check_straggler(self, step: int, dt: float):
        """Robust outlier detection on step times (paper's median engine)."""
        if len(self.step_times) < 8:
            return
        times = jnp.asarray(np.array(self.step_times[-64:], np.float32)
                            )[:, None]
        med = bitserial.median(times, bits=16)[0]
        mad = bitserial.median(jnp.abs(times - med), bits=16)[0]
        if dt > float(med) + self.tcfg.straggler_mad_factor * float(mad) \
                and float(mad) > 0:
            self.stragglers.append(step)
            print(f"[trainer] straggler: step {step} took {dt * 1e3:.0f} ms "
                  f"(median {float(med) * 1e3:.0f} ms)")
