from repro.runtime import trainer  # noqa: F401
