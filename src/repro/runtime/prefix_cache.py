"""Prefix-sharing admission cache for the paged clustered-KV engine.

Bursty, templated traffic — the dominant serving regime the paper's
request-processing half targets — sends many prompts that share a long
common prefix (system prompt, few-shot template, document header).  The
paged engine already stores every slot's exact tail ring as pool blocks
behind per-slot block tables with ref counts (runtime/kv_pool.py), so
two requests whose prompts agree on a prefix can point their tables at
the *same* physical blocks: K/V at position ``p`` is a pure function of
tokens ``[0, p]`` under causal attention, so the bytes are identical by
construction.  The streaming-clustering results this repo builds on
(He et al.; Mettu & Plaxton) make the same argument for the summaries:
the admission-time centroid state after ``F`` streamed tokens is a
deterministic function of those tokens alone, so it too can be reused
across requests instead of recomputed.

This module is the host-side index that makes that sharing safe:

  * **Entries are registered at chunk boundaries** of a chunked
    admission (``fed`` a multiple of ``prefill_chunk`` and strictly less
    than the prompt length).  At exactly those moments a slot's
    clustered state — centroids, counts, coverage frontier, and the live
    ring blocks — is *prefix-pure*: a function of ``tokens[:fed]``, the
    chunk size, and the compression config only.  (Anything later mixes
    in the prompt's total length via the final absorb target, and decode
    mixes in generated tokens; neither is shareable.)  Per-slot
    compaction gating in ``kv_compress.recompact_clustered`` keeps this
    true even when neighbouring slots force compaction passes at
    arbitrary engine steps.
  * An entry holds the prefix tokens themselves (hashes only route the
    lookup — equality is verified before any reuse), the ``(fed, cov)``
    pair, the live ring-block ids (each ``retain``-ed so donor exit or
    give-back cannot free the payload while the entry lives), and an
    opaque device snapshot of the slot's centroid rows taken by the
    engine.
  * **Shard locality**: block ids are only meaningful on the data shard
    that owns them, so the map is per shard and a request admitted on
    shard ``s`` can only reuse shard-``s`` entries
    (sharding/rules.block_table_spec keeps tables shard-local for the
    same reason).  The engine steers same-prefix admissions toward
    shards that already hold a matching entry.
  * **LRU + pressure eviction**: beyond ``max_entries`` per shard — or
    whenever the engine needs blocks back (pool pressure) — the least
    recently used entry releases its refs.  Entries are a cache, never
    an obligation: dropping one only costs re-prefilling.  Shorter
    prefixes of a registered stream are kept alongside longer ones: the
    chunk boundary just before a stream's unique suffix is the entry
    other suffixes actually hit.

Copy-on-write (kv_pool.ensure) is what keeps adopted blocks immutable:
any slot writing into a block with ``ref > 1`` gets a fresh copy first,
so an entry's payload can never be clobbered by a divergent suffix.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.kv_pool import BlockPool


@dataclasses.dataclass(frozen=True)
class PrefixShareConfig:
    """Engine-facing prefix-sharing knobs (ServerConfig.prefix_share).

    Requires the paged engine with chunked prefill: block-granular
    sharing needs the block pool, and prefix-pure registration points
    only exist on the chunked admission schedule.

    ``max_entries`` is the pinned-memory knob: every entry retains its
    live ring blocks (~keep_recent/block_size blocks), so a shard can
    pin up to ``max_entries`` windows of tail KV on top of the slots'
    own usage.  Single-template burst traffic wants it SMALL (1-2: one
    boundary per template is all that ever hits, and a tight cap keeps
    the physical peak below unshared serving — the regime
    benchmarks/run.py prefix_share pins); diverse prefixes or suffixes
    spanning several chunks want it larger so the boundary just before
    each stream's divergence stays registered.  Pool pressure evicts
    entries LRU-first regardless, so an oversized cap degrades to
    re-prefilling, never to PoolExhausted."""
    max_entries: int = 32     # LRU capacity per data shard
    min_prefix: int = 0       # shortest prefix worth an entry, in tokens
                              # (0 = one admission chunk)


@dataclasses.dataclass
class PrefixEntry:
    tokens: np.ndarray        # the prefix itself; verified on every hit
    fed: int                  # tokens streamed when the state was taken
    cov: int                  # coverage frontier at that point
    blocks: Dict[int, int]    # ring-block idx -> retained global block id
    snap: object              # device snapshot of the slot's clustered
                              # rows (k_cents/v_cents/counts/cov), taken
                              # and restored by the engine
    stamp: int = 0            # LRU clock
    hits: int = 0             # times this entry was adopted
    cluster: int = -1         # template_store traffic cluster (-1 = none)
    in_flight: int = 0        # adoptions between lookup and restore —
                              # a nonzero count pins the entry against
                              # eviction (see ``adoption_done``)


def _digest(tokens: np.ndarray) -> bytes:
    return hashlib.blake2b(np.ascontiguousarray(tokens, np.int32).tobytes(),
                           digest_size=16).digest()


class PrefixCache:
    """Per-data-shard prefix → (blocks, snapshot) map (host side)."""

    def __init__(self, cfg: PrefixShareConfig, n_shards: int,
                 pool: BlockPool):
        self.cfg = cfg
        self.pool = pool
        self._maps: List[Dict[Tuple[int, bytes], PrefixEntry]] = [
            {} for _ in range(max(n_shards, 1))]
        self._clock = 0
        self.hits = 0
        self.tokens_reused = 0

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------

    def _candidate_feds(self, prompt_len: int, chunk: int) -> List[int]:
        """Reusable prefix lengths for a prompt, longest first: chunk
        multiples strictly below the prompt length (at least one token
        must still stream through the model to produce the request's
        first logits), floored at min_prefix."""
        lo = max(self.cfg.min_prefix, chunk)
        top = ((prompt_len - 1) // chunk) * chunk
        return [f for f in range(top, lo - 1, -chunk)]

    def prefix_digests(self, prompt: np.ndarray,
                       chunk: int) -> List[Tuple[int, bytes]]:
        """Candidate (fed, digest) pairs for a prompt, longest first.
        Hashing is the only O(prompt²/chunk) part of a lookup — the
        engine computes this once per request and passes it to every
        ``match_len``/``lookup`` instead of re-hashing per shard per
        engine step while the request queues."""
        return [(f, _digest(prompt[:f]))
                for f in self._candidate_feds(len(prompt), chunk)]

    def match_len(self, shard: int, prompt: np.ndarray, chunk: int,
                  digests: Optional[List[Tuple[int, bytes]]] = None) -> int:
        """Longest reusable prefix length available on ``shard`` (0 =
        none) — admission steering, no LRU side effects."""
        m = self._maps[shard]
        for fed, dig in (digests if digests is not None
                         else self.prefix_digests(prompt, chunk)):
            e = m.get((fed, dig))
            if e is not None and np.array_equal(e.tokens, prompt[:fed]):
                return fed
        return 0

    def lookup(self, shard: int, prompt: np.ndarray, chunk: int,
               digests: Optional[List[Tuple[int, bytes]]] = None,
               ) -> Optional[PrefixEntry]:
        """Longest verified entry matching the prompt on ``shard``.

        The returned entry is marked **in flight**: until the caller
        declares ``adoption_done(entry)`` it cannot be evicted, so a
        pool-pressure reclaim landing between the match and the
        block-adopt/snapshot-restore can never release the blocks the
        admitting slot is about to resume from."""
        m = self._maps[shard]
        for fed, dig in (digests if digests is not None
                         else self.prefix_digests(prompt, chunk)):
            e = m.get((fed, dig))
            if e is not None and np.array_equal(e.tokens, prompt[:fed]):
                self._clock += 1
                e.stamp = self._clock
                e.hits += 1
                e.in_flight += 1
                self.hits += 1
                self.tokens_reused += fed
                return e
        return None

    def adoption_done(self, entry: PrefixEntry) -> None:
        """Release the in-flight pin taken by ``lookup`` — the adopting
        slot holds its own block refs (``pool.adopt``) and has restored
        the snapshot, so the entry is evictable again."""
        if entry.in_flight <= 0:
            raise ValueError("adoption_done without a matching lookup")
        entry.in_flight -= 1

    # ------------------------------------------------------------------
    # registration / eviction
    # ------------------------------------------------------------------

    def register(self, shard: int, prompt: np.ndarray, fed: int, cov: int,
                 blocks: Dict[int, int], snap, cluster: int = -1) -> bool:
        """Register the prefix state at ``fed`` tokens.  Retains every
        listed block.  Returns False (and retains nothing) when an
        identical entry already exists.

        Shorter prefixes of the same tokens are deliberately KEPT: the
        boundary just before a stream's unique suffix (e.g. the pure
        template) is exactly the entry later requests with *different*
        suffixes will hit — evicting it when the stream registers a
        suffix-contaminated longer boundary would collapse the hit rate
        whenever suffixes exceed one chunk.  Capacity is bounded by the
        per-shard LRU cap here and by pool-pressure eviction in the
        engine instead."""
        m = self._maps[shard]
        key = (fed, _digest(prompt[:fed]))
        if key in m:
            self._clock += 1
            m[key].stamp = self._clock
            return False
        for gid in blocks.values():
            self.pool.retain(gid)
        self._clock += 1
        m[key] = PrefixEntry(tokens=np.array(prompt[:fed], np.int32),
                             fed=fed, cov=cov, blocks=dict(blocks),
                             snap=snap, stamp=self._clock, cluster=cluster)
        while len(m) > self.cfg.max_entries:
            if not self.evict_lru(shard):
                break   # every other entry is mid-adoption: over-stay
        return True

    def _drop(self, shard: int, key) -> None:
        e = self._maps[shard].pop(key)
        for gid in e.blocks.values():
            self.pool.release(gid)

    def evict_lru(self, shard: int) -> bool:
        """Release the least recently used entry's blocks (pool-pressure
        reclaim).  Entries with an adoption in flight are pinned — the
        admitting slot has matched but not yet adopted/restored, and
        evicting under it would hand its blocks back to the free list
        mid-resume.  Returns False when nothing is evictable."""
        m = self._maps[shard]
        cands = [k for k, e in m.items() if e.in_flight == 0]
        if not cands:
            return False
        key = min(cands, key=lambda k: m[k].stamp)
        self._drop(shard, key)
        return True

    def entries(self, shard: int) -> int:
        return len(self._maps[shard])

    def clear(self) -> None:
        """Release every entry (end of serve: the pool must drain).
        Raises if an adoption is still in flight — by the time a serve
        drains, every ``lookup`` has seen its ``adoption_done``."""
        for shard in range(len(self._maps)):
            for key in list(self._maps[shard]):
                if self._maps[shard][key].in_flight:
                    raise RuntimeError(
                        "clear with an adoption in flight — the engine "
                        "must finish restoring before the cache drops "
                        "the entry under it")
                self._drop(shard, key)
